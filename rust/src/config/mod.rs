//! Typed experiment configuration: every knob of Algorithm 1 and of the
//! baselines — including the compute [`BackendKind`] — loadable from a
//! JSON file and overridable from the CLI (`--backend native|pjrt`).
//!
//! Defaults follow the paper's experimental setup (Section 5.2): m = 4
//! workers, τ = 8, B = 64 (taken from the model profile), RI-SGD
//! redundancy μ_r = 0.25, smoothing μ = 1/√(dN) (Theorem 1), and the
//! theory step size α = √(Bm)/(L√N) with a configurable smoothness guess.

use std::fmt;
use std::path::Path;
use std::str::FromStr;

use anyhow::{anyhow, Context, Result};

use crate::backend::{BackendKind, ComputeMode};
use crate::comm::NetworkModel;
use crate::util::json::Json;

/// The algorithms of the paper's evaluation (Table 1 / Figs. 1–2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Method {
    /// the paper's contribution (Algorithm 1)
    HoSgd,
    /// fully synchronous distributed SGD (Wang & Joshi 2018)
    SyncSgd,
    /// model averaging with infused redundancy (Haddadpour et al. 2019)
    RiSgd,
    /// distributed zeroth-order SGD (Sahu et al. 2019)
    ZoSgd,
    /// zeroth-order SVRG, averaged variant (Liu et al. 2018)
    ZoSvrgAve,
    /// quantized SGD (Alistarh et al. 2017)
    Qsgd,
    /// momentum extension of Algorithm 1 (this repo's future-work feature)
    HoSgdM,
}

impl Method {
    pub const ALL: [Method; 6] = [
        Method::HoSgd,
        Method::SyncSgd,
        Method::RiSgd,
        Method::ZoSgd,
        Method::ZoSvrgAve,
        Method::Qsgd,
    ];

    /// The five methods in the paper's figures (QSGD only appears in
    /// Table 1).
    pub const FIGURE_SET: [Method; 5] = [
        Method::HoSgd,
        Method::SyncSgd,
        Method::RiSgd,
        Method::ZoSgd,
        Method::ZoSvrgAve,
    ];

    pub fn label(&self) -> &'static str {
        match self {
            Method::HoSgd => "ho_sgd",
            Method::SyncSgd => "sync_sgd",
            Method::RiSgd => "ri_sgd",
            Method::ZoSgd => "zo_sgd",
            Method::ZoSvrgAve => "zo_svrg_ave",
            Method::Qsgd => "qsgd",
            Method::HoSgdM => "ho_sgd_m",
        }
    }

    pub fn paper_name(&self) -> &'static str {
        match self {
            Method::HoSgd => "HO-SGD (proposed)",
            Method::SyncSgd => "syncSGD",
            Method::RiSgd => "RI-SGD",
            Method::ZoSgd => "ZO-SGD",
            Method::ZoSvrgAve => "ZO-SVRG-Ave",
            Method::Qsgd => "QSGD",
            Method::HoSgdM => "HO-SGD+M (ext)",
        }
    }

    /// Does this method ever call the first-order oracle?
    pub fn uses_fo(&self) -> bool {
        !matches!(self, Method::ZoSgd | Method::ZoSvrgAve)
    }

    /// Extensions implemented beyond the paper's method set.
    pub const EXTENSIONS: [Method; 1] = [Method::HoSgdM];
}

impl FromStr for Method {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().replace('-', "_").as_str() {
            "ho_sgd" | "hosgd" | "proposed" => Ok(Method::HoSgd),
            "sync_sgd" | "syncsgd" | "sync" => Ok(Method::SyncSgd),
            "ri_sgd" | "risgd" | "ri" => Ok(Method::RiSgd),
            "zo_sgd" | "zosgd" | "zo" => Ok(Method::ZoSgd),
            "zo_svrg_ave" | "zo_svrg" | "zosvrg" => Ok(Method::ZoSvrgAve),
            "qsgd" => Ok(Method::Qsgd),
            "ho_sgd_m" | "hosgdm" | "ho_sgd_momentum" => Ok(Method::HoSgdM),
            other => Err(anyhow!("unknown method {other:?}")),
        }
    }
}

impl fmt::Display for Method {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.label())
    }
}

/// Deterministic fault injection on the `Loopback` communication fabric:
/// per-worker straggler latency and seeded drop-with-retry, so failure
/// scenarios run in CI with bit-reproducible counters. Numerics are never
/// affected — a retried round-trip recomputes the identical result; only
/// the measured wire accounting and the modelled critical path change.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    /// injected per-round-trip latency seconds per worker, cycled over
    /// ranks (`latency_s[rank % len]`); empty = no injected latency
    pub latency_s: Vec<f64>,
    /// probability in [0, 1) that a worker's round-trip is dropped and
    /// retried (deterministic, seeded per `(iteration, rank, attempt)`)
    pub drop_prob: f64,
    /// seed of the drop stream (independent of the run seed so fault
    /// scenarios can vary without changing the trajectory)
    pub seed: u64,
}

impl FaultPlan {
    /// Does this plan inject anything at all?
    pub fn is_active(&self) -> bool {
        self.drop_prob > 0.0 || self.latency_s.iter().any(|&l| l > 0.0)
    }
}

/// Communication-fabric selection: which [`crate::transport::Transport`]
/// carries the coordinator↔worker rounds.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TransportConfig {
    /// `host:port` addresses of `hosgd worker --listen` daemons; empty ⇒
    /// the in-process `Loopback` fabric. Logical worker ranks are assigned
    /// round-robin over the addresses. NOT part of the run identity:
    /// traces are byte-identical across fabrics, so a checkpointed TCP run
    /// may resume in-process and vice versa.
    pub workers_at: Vec<String>,
    /// fault injection (Loopback only — rejected with `workers_at`)
    pub fault: FaultPlan,
    /// bounded-staleness run-ahead window W for pipelineable rounds
    /// (RI-SGD local steps between averaging points): the coordinator may
    /// have up to W rounds in flight before blocking on the oldest. W = 0
    /// (default) is the fully synchronous exchange and reproduces the
    /// canonical traces bit-for-bit; W > 0 keeps the trajectory and byte
    /// counters identical but shifts when latency/bytes are charged (rows
    /// account in-flight rounds when they complete). Part of the run
    /// identity (fingerprinted).
    pub staleness_window: usize,
}

/// Step-size rule. `Theory` is Theorem 1's α = √(Bm)/(L√N).
#[derive(Debug, Clone, Copy)]
pub enum StepSize {
    Constant { alpha: f64 },
    /// α_t = alpha0 / (1 + gamma·t)
    InvDecay { alpha0: f64, gamma: f64 },
    /// Theorem 1's rule with smoothness guess `l_guess`
    Theory { l_guess: f64 },
}

impl StepSize {
    pub fn at(&self, t: u64, batch: usize, m: usize, n_total: u64) -> f64 {
        match *self {
            StepSize::Constant { alpha } => alpha,
            StepSize::InvDecay { alpha0, gamma } => alpha0 / (1.0 + gamma * t as f64),
            StepSize::Theory { l_guess } => {
                ((batch * m) as f64).sqrt() / (l_guess * (n_total as f64).sqrt())
            }
        }
    }
}

/// Full training-run configuration.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub method: Method,
    /// compute backend serving the model (`native` | `pjrt`)
    pub backend: BackendKind,
    /// model/dataset profile name (must exist in the artifact manifest)
    pub dataset: String,
    /// N — total iterations
    pub iters: u64,
    /// m — number of worker nodes
    pub workers: usize,
    /// τ — period of first-order exchanges (HO-SGD) / model averaging
    /// (RI-SGD)
    pub tau: usize,
    /// μ — ZO smoothing parameter; None ⇒ Theorem 1's 1/√(dN)
    pub mu: Option<f64>,
    pub step: StepSize,
    pub seed: u64,
    /// evaluate test accuracy every this many iterations (0 = never)
    pub eval_every: u64,
    /// record a trace row every this many iterations
    pub record_every: u64,
    /// write a v2 run-state checkpoint every this many iterations
    /// (0 = never). Driver-level: does not affect the trajectory, so it is
    /// not part of the resume-compatibility fingerprint.
    pub checkpoint_every: u64,
    pub train_size: usize,
    pub test_size: usize,
    /// RI-SGD redundancy factor μ_r
    pub redundancy: f64,
    /// ZO-SVRG epoch length (q) and #probe directions per estimate
    pub svrg_epoch: usize,
    pub svrg_probes: usize,
    /// QSGD quantization levels s
    pub qsgd_levels: u32,
    /// QSGD error-feedback (EF) memory — keeps the quantization residual
    /// locally and re-injects it next round (extension; default off = the
    /// paper's plain QSGD)
    pub qsgd_error_feedback: bool,
    /// heavy-ball coefficient for the HO-SGD+M extension
    pub momentum: f64,
    pub network: NetworkModel,
    /// worker-pool lanes for the parallel execution engine (0 ⇒ available
    /// parallelism). Traces are bit-identical at any value — the fan-out
    /// reduces per-worker results in fixed worker order. NOTE: when the
    /// model binding brings its own pool ([`crate::backend::ModelBackend::pool`],
    /// as the native backend does), that pool — sized at backend
    /// construction — takes precedence; this key sizes the run's pool only
    /// for pool-less bindings (e.g. pjrt). The CLI passes `--threads` to
    /// both places, so they cannot diverge there.
    pub threads: usize,
    /// loss-reduction precision of the native backend (`f64` = golden-exact
    /// default; `f32` = fast mode with widened golden tolerances — see
    /// [`ComputeMode`]). Part of the run identity: f32 traces differ from
    /// f64 traces, so the resume fingerprint hashes this knob.
    pub compute: ComputeMode,
    /// the communication fabric (Loopback vs TCP worker daemons + faults)
    pub transport: TransportConfig,
}

impl Default for TrainConfig {
    fn default() -> Self {
        Self {
            method: Method::HoSgd,
            backend: BackendKind::Native,
            dataset: "sensorless".into(),
            iters: 400,
            workers: 4,      // paper §5.2
            tau: 8,          // paper §5.2
            mu: None,        // Theorem 1 rule
            step: StepSize::Constant { alpha: 0.05 },
            seed: 1,
            eval_every: 20,
            record_every: 1,
            checkpoint_every: 0,
            train_size: 0, // 0 ⇒ profile default
            test_size: 0,
            redundancy: 0.25, // paper §5.2
            svrg_epoch: 10,
            svrg_probes: 4,
            qsgd_levels: 4,
            qsgd_error_feedback: false,
            momentum: 0.9,
            network: NetworkModel::default(),
            threads: 0, // auto
            compute: ComputeMode::F64,
            transport: TransportConfig::default(),
        }
    }
}

impl TrainConfig {
    /// Every key [`TrainConfig::from_json`] reads. Kept next to the
    /// parser so document validators (the sweep plan parser rejects
    /// unknown keys loudly; `from_json` itself ignores them) cannot
    /// silently drift when a knob is added.
    pub const JSON_KEYS: [&str; 26] = [
        "method",
        "backend",
        "dataset",
        "iters",
        "workers",
        "tau",
        "mu",
        "step",
        "seed",
        "eval_every",
        "record_every",
        "checkpoint_every",
        "train_size",
        "test_size",
        "redundancy",
        "svrg_epoch",
        "svrg_probes",
        "qsgd_levels",
        "qsgd_error_feedback",
        "momentum",
        "threads",
        "compute",
        "network",
        "workers_at",
        "fault",
        "staleness_window",
    ];

    /// Theorem 1's smoothing rule μ = 1/√(dN).
    pub fn resolve_mu(&self, d: usize) -> f64 {
        self.mu.unwrap_or_else(|| 1.0 / ((d as f64) * (self.iters as f64)).sqrt())
    }

    pub fn validate(&self) -> Result<()> {
        if self.iters == 0 {
            return Err(anyhow!("iters must be > 0"));
        }
        if self.workers == 0 {
            return Err(anyhow!("workers must be > 0"));
        }
        if self.tau == 0 {
            return Err(anyhow!("tau must be >= 1"));
        }
        if let Some(mu) = self.mu {
            if mu <= 0.0 {
                return Err(anyhow!("mu must be positive"));
            }
        }
        if !(0.0..=1.0).contains(&self.redundancy) {
            return Err(anyhow!("redundancy must be in [0,1]"));
        }
        if self.qsgd_levels == 0 {
            return Err(anyhow!("qsgd_levels must be >= 1"));
        }
        if self.svrg_epoch == 0 || self.svrg_probes == 0 {
            return Err(anyhow!("svrg_epoch and svrg_probes must be >= 1"));
        }
        if !(0.0..1.0).contains(&self.momentum) {
            return Err(anyhow!("momentum must be in [0,1)"));
        }
        if !(0.0..1.0).contains(&self.transport.fault.drop_prob) {
            return Err(anyhow!("fault drop_prob must be in [0,1)"));
        }
        if self.transport.fault.latency_s.iter().any(|&l| l < 0.0 || !l.is_finite()) {
            return Err(anyhow!("fault latency_s entries must be finite and >= 0"));
        }
        if !self.transport.workers_at.is_empty() && self.transport.fault.is_active() {
            return Err(anyhow!(
                "fault injection is Loopback-only; drop the fault plan or --workers-at"
            ));
        }
        Ok(())
    }

    /// Load from a JSON file; absent keys keep their defaults.
    pub fn from_json_file(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        let v = Json::parse(&text).context("parsing JSON config")?;
        let cfg = Self::from_json(&v)?;
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn from_json(v: &Json) -> Result<Self> {
        let mut cfg = Self::default();
        let gs = |k: &str| v.get(k).and_then(Json::as_str);
        let gn = |k: &str| v.get(k).and_then(Json::as_f64);
        if let Some(s) = gs("method") {
            cfg.method = s.parse()?;
        }
        if let Some(s) = gs("backend") {
            cfg.backend = s.parse()?;
        }
        if let Some(s) = gs("dataset") {
            cfg.dataset = s.to_string();
        }
        if let Some(x) = gn("iters") {
            cfg.iters = x as u64;
        }
        if let Some(x) = gn("workers") {
            cfg.workers = x as usize;
        }
        if let Some(x) = gn("tau") {
            cfg.tau = x as usize;
        }
        if let Some(x) = gn("mu") {
            cfg.mu = Some(x);
        }
        if let Some(step) = v.get("step") {
            cfg.step = StepSize::from_json(step)?;
        }
        if let Some(x) = gn("seed") {
            cfg.seed = x as u64;
        }
        if let Some(x) = gn("eval_every") {
            cfg.eval_every = x as u64;
        }
        if let Some(x) = gn("record_every") {
            cfg.record_every = x as u64;
        }
        if let Some(x) = gn("checkpoint_every") {
            cfg.checkpoint_every = x as u64;
        }
        if let Some(x) = gn("train_size") {
            cfg.train_size = x as usize;
        }
        if let Some(x) = gn("test_size") {
            cfg.test_size = x as usize;
        }
        if let Some(x) = gn("redundancy") {
            cfg.redundancy = x;
        }
        if let Some(x) = gn("svrg_epoch") {
            cfg.svrg_epoch = x as usize;
        }
        if let Some(x) = gn("svrg_probes") {
            cfg.svrg_probes = x as usize;
        }
        if let Some(x) = gn("qsgd_levels") {
            cfg.qsgd_levels = x as u32;
        }
        if let Some(b) = v.get("qsgd_error_feedback").and_then(Json::as_bool) {
            cfg.qsgd_error_feedback = b;
        }
        if let Some(x) = gn("momentum") {
            cfg.momentum = x;
        }
        if let Some(x) = gn("threads") {
            cfg.threads = x as usize;
        }
        if let Some(s) = gs("compute") {
            cfg.compute = s.parse()?;
        }
        if let Some(n) = v.get("network") {
            if let (Some(lat), Some(bw)) = (
                n.get("latency_s").and_then(Json::as_f64),
                n.get("bandwidth_bps").and_then(Json::as_f64),
            ) {
                cfg.network = NetworkModel { latency_s: lat, bandwidth_bps: bw };
            }
        }
        if let Some(ws) = v.get("workers_at").and_then(Json::as_arr) {
            cfg.transport.workers_at =
                ws.iter().filter_map(|a| a.as_str().map(String::from)).collect();
        }
        if let Some(x) = gn("staleness_window") {
            cfg.transport.staleness_window = x as usize;
        }
        if let Some(fv) = v.get("fault") {
            if let Some(lat) = fv.get("latency_s").and_then(Json::as_arr) {
                cfg.transport.fault.latency_s = lat.iter().filter_map(Json::as_f64).collect();
            }
            if let Some(p) = fv.get("drop_prob").and_then(Json::as_f64) {
                cfg.transport.fault.drop_prob = p;
            }
            if let Some(s) = fv.get("seed").and_then(Json::as_f64) {
                cfg.transport.fault.seed = s as u64;
            }
        }
        Ok(cfg)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("method", Json::str(self.method.label())),
            ("backend", Json::str(self.backend.label())),
            ("dataset", Json::str(self.dataset.clone())),
            ("iters", Json::num(self.iters as f64)),
            ("workers", Json::num(self.workers as f64)),
            ("tau", Json::num(self.tau as f64)),
            (
                "mu",
                self.mu.map_or(Json::Null, Json::num),
            ),
            ("step", self.step.to_json()),
            ("seed", Json::num(self.seed as f64)),
            ("eval_every", Json::num(self.eval_every as f64)),
            ("record_every", Json::num(self.record_every as f64)),
            ("checkpoint_every", Json::num(self.checkpoint_every as f64)),
            ("train_size", Json::num(self.train_size as f64)),
            ("test_size", Json::num(self.test_size as f64)),
            ("redundancy", Json::num(self.redundancy)),
            ("svrg_epoch", Json::num(self.svrg_epoch as f64)),
            ("svrg_probes", Json::num(self.svrg_probes as f64)),
            ("qsgd_levels", Json::num(self.qsgd_levels as f64)),
            ("qsgd_error_feedback", Json::Bool(self.qsgd_error_feedback)),
            ("momentum", Json::num(self.momentum)),
            ("threads", Json::num(self.threads as f64)),
            ("compute", Json::str(self.compute.label())),
            (
                "network",
                Json::obj(vec![
                    ("latency_s", Json::num(self.network.latency_s)),
                    ("bandwidth_bps", Json::num(self.network.bandwidth_bps)),
                ]),
            ),
            (
                "workers_at",
                Json::Arr(self.transport.workers_at.iter().map(Json::str).collect()),
            ),
            ("staleness_window", Json::num(self.transport.staleness_window as f64)),
            (
                "fault",
                Json::obj(vec![
                    (
                        "latency_s",
                        Json::Arr(
                            self.transport.fault.latency_s.iter().copied().map(Json::num).collect(),
                        ),
                    ),
                    ("drop_prob", Json::num(self.transport.fault.drop_prob)),
                    ("seed", Json::num(self.transport.fault.seed as f64)),
                ]),
            ),
        ])
    }
}

impl StepSize {
    pub fn from_json(v: &Json) -> Result<Self> {
        let kind = v.req("kind")?.as_str().unwrap_or("constant");
        match kind {
            "constant" => Ok(StepSize::Constant {
                alpha: v.req("alpha")?.as_f64().ok_or_else(|| anyhow!("alpha not a number"))?,
            }),
            "inv_decay" => Ok(StepSize::InvDecay {
                alpha0: v.req("alpha0")?.as_f64().ok_or_else(|| anyhow!("alpha0"))?,
                gamma: v.req("gamma")?.as_f64().ok_or_else(|| anyhow!("gamma"))?,
            }),
            "theory" => Ok(StepSize::Theory {
                l_guess: v.req("l_guess")?.as_f64().ok_or_else(|| anyhow!("l_guess"))?,
            }),
            other => Err(anyhow!("unknown step-size kind {other:?}")),
        }
    }

    pub fn to_json(&self) -> Json {
        match *self {
            StepSize::Constant { alpha } => Json::obj(vec![
                ("kind", Json::str("constant")),
                ("alpha", Json::num(alpha)),
            ]),
            StepSize::InvDecay { alpha0, gamma } => Json::obj(vec![
                ("kind", Json::str("inv_decay")),
                ("alpha0", Json::num(alpha0)),
                ("gamma", Json::num(gamma)),
            ]),
            StepSize::Theory { l_guess } => Json::obj(vec![
                ("kind", Json::str("theory")),
                ("l_guess", Json::num(l_guess)),
            ]),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn method_parse_aliases() {
        assert_eq!("HO-SGD".parse::<Method>().unwrap(), Method::HoSgd);
        assert_eq!("proposed".parse::<Method>().unwrap(), Method::HoSgd);
        assert_eq!("syncsgd".parse::<Method>().unwrap(), Method::SyncSgd);
        assert_eq!("zo_svrg".parse::<Method>().unwrap(), Method::ZoSvrgAve);
        assert!("nope".parse::<Method>().is_err());
    }

    #[test]
    fn default_config_is_valid_and_paperlike() {
        let c = TrainConfig::default();
        c.validate().unwrap();
        assert_eq!(c.workers, 4);
        assert_eq!(c.tau, 8);
        assert_eq!(c.redundancy, 0.25);
    }

    #[test]
    fn mu_rule_matches_theorem1() {
        let c = TrainConfig { iters: 400, mu: None, ..Default::default() };
        let d = 10_000;
        let mu = c.resolve_mu(d);
        assert!((mu - 1.0 / ((d as f64 * 400.0).sqrt())).abs() < 1e-12);
        let c2 = TrainConfig { mu: Some(0.01), ..Default::default() };
        assert_eq!(c2.resolve_mu(d), 0.01);
    }

    #[test]
    fn validation_catches_bad_values() {
        let mut c = TrainConfig { iters: 0, ..Default::default() };
        assert!(c.validate().is_err());
        c.iters = 1;
        c.tau = 0;
        assert!(c.validate().is_err());
        c.tau = 1;
        c.redundancy = 1.5;
        assert!(c.validate().is_err());
    }

    #[test]
    fn json_roundtrip() {
        let c = TrainConfig {
            mu: Some(0.01),
            backend: BackendKind::Pjrt,
            threads: 4,
            compute: ComputeMode::F32,
            ..Default::default()
        };
        let text = c.to_json().pretty();
        let back = TrainConfig::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.method, c.method);
        assert_eq!(back.backend, BackendKind::Pjrt);
        assert_eq!(back.tau, c.tau);
        assert_eq!(back.dataset, c.dataset);
        assert_eq!(back.mu, c.mu);
        assert_eq!(back.qsgd_levels, c.qsgd_levels);
        assert_eq!(back.threads, 4);
        assert_eq!(back.compute, ComputeMode::F32);
        // absent key keeps the golden-exact default
        let v = Json::parse(r#"{"method": "zo_sgd"}"#).unwrap();
        assert_eq!(TrainConfig::from_json(&v).unwrap().compute, ComputeMode::F64);
    }

    #[test]
    fn threads_defaults_to_auto_and_loads_from_json() {
        assert_eq!(TrainConfig::default().threads, 0);
        let v = Json::parse(r#"{"threads": 2}"#).unwrap();
        assert_eq!(TrainConfig::from_json(&v).unwrap().threads, 2);
    }

    #[test]
    fn json_partial_keeps_defaults() {
        let v = Json::parse(r#"{"method": "zo_sgd", "iters": 9}"#).unwrap();
        let c = TrainConfig::from_json(&v).unwrap();
        assert_eq!(c.method, Method::ZoSgd);
        assert_eq!(c.iters, 9);
        assert_eq!(c.tau, TrainConfig::default().tau);
        assert_eq!(c.checkpoint_every, 0);
    }

    #[test]
    fn checkpoint_every_roundtrips_through_json() {
        let c = TrainConfig { checkpoint_every: 25, ..Default::default() };
        let back = TrainConfig::from_json(&Json::parse(&c.to_json().pretty()).unwrap()).unwrap();
        assert_eq!(back.checkpoint_every, 25);
    }

    #[test]
    fn transport_config_roundtrips_and_validates() {
        let c = TrainConfig {
            transport: TransportConfig {
                workers_at: Vec::new(),
                fault: FaultPlan { latency_s: vec![0.0, 1e-3], drop_prob: 0.25, seed: 9 },
                staleness_window: 3,
            },
            ..Default::default()
        };
        c.validate().unwrap();
        let back = TrainConfig::from_json(&Json::parse(&c.to_json().pretty()).unwrap()).unwrap();
        assert_eq!(back.transport, c.transport);
        assert_eq!(back.transport.staleness_window, 3);
        assert!(back.transport.fault.is_active());
        assert!(!TrainConfig::default().transport.fault.is_active());

        // workers_at list round-trips too
        let c2 = TrainConfig {
            transport: TransportConfig {
                workers_at: vec!["127.0.0.1:7401".into(), "127.0.0.1:7402".into()],
                fault: FaultPlan::default(),
                staleness_window: 0,
            },
            ..Default::default()
        };
        c2.validate().unwrap();
        let back2 = TrainConfig::from_json(&Json::parse(&c2.to_json().pretty()).unwrap()).unwrap();
        assert_eq!(back2.transport.workers_at, c2.transport.workers_at);

        // fault injection is loopback-only; drop_prob must be a probability
        let bad = TrainConfig {
            transport: TransportConfig {
                workers_at: vec!["h:1".into()],
                fault: FaultPlan { latency_s: Vec::new(), drop_prob: 0.5, seed: 0 },
                staleness_window: 0,
            },
            ..Default::default()
        };
        assert!(bad.validate().unwrap_err().to_string().contains("Loopback-only"));
        let bad2 = TrainConfig {
            transport: TransportConfig {
                workers_at: Vec::new(),
                fault: FaultPlan { latency_s: Vec::new(), drop_prob: 1.5, seed: 0 },
                staleness_window: 0,
            },
            ..Default::default()
        };
        assert!(bad2.validate().is_err());
    }

    #[test]
    fn step_size_rules() {
        let s = StepSize::Constant { alpha: 0.1 };
        assert_eq!(s.at(100, 64, 4, 1000), 0.1);
        let d = StepSize::InvDecay { alpha0: 1.0, gamma: 1.0 };
        assert!(d.at(9, 64, 4, 1000) < d.at(0, 64, 4, 1000));
        let t = StepSize::Theory { l_guess: 10.0 };
        // α = sqrt(64*4) / (10 * sqrt(400)) = 16 / 200
        assert!((t.at(0, 64, 4, 400) - 0.08).abs() < 1e-12);
    }
}
