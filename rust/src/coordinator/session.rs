//! The session-based training driver: a steppable, observable, resumable
//! replacement for the monolithic `run_train` loop.
//!
//! A [`Session`] owns one run — the per-worker [`World`] (including its
//! communication fabric), the [`Algorithm`], the observers — and exposes
//! the paper's iteration schedule one step at a time: [`Session::step`]
//! executes a single hybrid FO/ZO iteration, [`Session::run_until`] /
//! [`Session::run_to_end`] drive ranges of them. Everything the old loop
//! hard-coded (trace recording, periodic test evaluation, checkpoint
//! cadence) is delivered through the [`Observer`] trait — the built-in
//! [`TraceRecorder`] builds the [`Trace`], [`PeriodicCheckpoint`] gives
//! embedders `--checkpoint-every` semantics, and the streaming sinks in
//! [`crate::metrics::sinks`] append rows to disk as they happen.
//!
//! The session is generic over the [`Oracle`]: [`Session::new`] builds the
//! Section 5.2 training run (a [`TrainOracle`] over a backend-bound model
//! + dataset, with test-set evaluation), while [`Session::with_oracle`]
//! drives any other objective — the Section 5.1 attack loop runs through
//! it (see [`crate::attack::run_attack`]) with the identical schedule,
//! events and counters.
//!
//! Worker execution crosses the [`Transport`] fabric configured in
//! [`TrainConfig::transport`]: the in-process `Loopback` by default, or
//! remote `hosgd worker` daemons via `workers_at` — with canonical traces
//! byte-identical either way.
//!
//! Sessions snapshot and restore: [`Session::snapshot`] captures the full
//! [`RunState`] (optimizer buffers, comm/compute accounting, recorded
//! rows, iteration cursor) and [`Session::restore`] resumes it
//! **bit-identically** — the canonical trace of an interrupted+resumed run
//! is byte-equal to an uninterrupted one, at any thread count and on any
//! fabric. No RNG position needs saving: every stream (directions,
//! minibatches, QSGD quantization, fault-injection drops) is re-derived
//! from `(seed, iter, worker)`.

use std::collections::VecDeque;
use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{bail, Result};

use crate::backend::ModelBackend;
use crate::comm::CommSim;
use crate::config::{StepSize, TrainConfig};
use crate::coordinator::checkpoint::{RunMeta, RunState};
use crate::coordinator::{eval_accuracy, RunData, TrainOutcome};
use crate::metrics::{Stopwatch, Trace, TraceRow};
use crate::optim::{build, AlgoConfig, Algorithm, Oracle, TrainOracle, World};
use crate::pool::{resolve_threads, WorkerPool};
use crate::rng::hash_u64s;
use crate::telemetry::trace::DrainedRing;
use crate::telemetry::{Attr, Recorder};
use crate::transport::{Loopback, TcpTransport, Transport};

// ---------------------------------------------------------------------------
// Observer: streaming run events
// ---------------------------------------------------------------------------

/// What one completed iteration looked like. `row` carries the loss,
/// optional test accuracy and the cumulative comm/compute accounting at
/// this iteration (the same fields a recorded [`TraceRow`] would hold).
#[derive(Debug, Clone, Copy)]
pub struct StepEvent {
    pub row: TraceRow,
    /// whether the built-in recorder keeps this row (the `record_every` /
    /// `eval_every` / final-iteration cadence of [`TrainConfig`])
    pub recorded: bool,
    /// whether this iteration exchanged a full vector per worker (FO
    /// all-reduce, RI-SGD model average, QSGD encoded gradient) rather
    /// than the ZO scalar
    pub sync_round: bool,
    /// `true` on iteration `N-1` — the run is complete after this event
    pub final_step: bool,
}

/// A periodic (or on-demand) test-set evaluation.
#[derive(Debug, Clone, Copy)]
pub struct EvalEvent {
    pub iter: u64,
    /// test accuracy in [0, 1]
    pub accuracy: f64,
}

/// A vector-level synchronization round (the expensive exchanges the
/// paper's τ schedule spaces out).
#[derive(Debug, Clone, Copy)]
pub struct SyncEvent {
    pub iter: u64,
    /// per-worker egress bytes of this round (modelled collective cost)
    pub bytes: u64,
    /// per-worker scalars of this round
    pub scalars: u64,
}

/// Streaming hooks over a running [`Session`]. All methods default to
/// no-ops; implement the ones you care about. Within one iteration the
/// dispatch order is `on_sync_round` → `on_eval` → `on_step` →
/// `wants_snapshot`/`on_snapshot`.
pub trait Observer {
    fn on_step(&mut self, _ev: &StepEvent) {}
    fn on_eval(&mut self, _ev: &EvalEvent) {}
    fn on_sync_round(&mut self, _ev: &SyncEvent) {}

    /// Return `true` to receive a [`RunState`] snapshot for this step via
    /// [`Observer::on_snapshot`]. The session builds the snapshot at most
    /// once per step and shares it among all observers that asked, so the
    /// predicate must be cheap and is queried exactly once per step.
    fn wants_snapshot(&mut self, _ev: &StepEvent) -> bool {
        false
    }

    /// Receive the snapshot requested by [`Observer::wants_snapshot`]. An
    /// error here aborts [`Session::step`] — checkpoint persistence
    /// failures should be loud, not silently dropped.
    fn on_snapshot(&mut self, _state: &RunState) -> Result<()> {
        Ok(())
    }
}

/// The observer that builds the run's [`Trace`]: keeps every row whose
/// [`StepEvent::recorded`] flag is set. A `Session` carries one internally
/// (its rows survive snapshot/restore); it is public so embedders driving
/// a custom loop can reuse the exact recording semantics.
#[derive(Debug, Default, Clone)]
pub struct TraceRecorder {
    pub rows: Vec<TraceRow>,
}

impl Observer for TraceRecorder {
    fn on_step(&mut self, ev: &StepEvent) {
        if ev.recorded {
            self.rows.push(ev.row);
        }
    }
}

/// The `--checkpoint-every N` semantics as a reusable [`Observer`]: every
/// `every`-th completed iteration, persist the session's [`RunState`] to
/// `path` (atomic overwrite of the same file). The CLI train path is built
/// on this; embedders get identical behavior with one `add_observer`.
#[derive(Debug, Clone)]
pub struct PeriodicCheckpoint {
    every: u64,
    path: PathBuf,
}

impl PeriodicCheckpoint {
    /// Checkpoint to `path` every `every` completed iterations (`0`
    /// disables — the observer becomes a no-op).
    pub fn new(every: u64, path: impl Into<PathBuf>) -> Self {
        Self { every, path: path.into() }
    }
}

impl Observer for PeriodicCheckpoint {
    fn wants_snapshot(&mut self, ev: &StepEvent) -> bool {
        // ev.row.iter is the just-executed iteration t; t+1 iterations are
        // now complete — the same cadence the CLI loop used to hand-roll
        self.every > 0 && (ev.row.iter + 1) % self.every == 0
    }

    fn on_snapshot(&mut self, state: &RunState) -> Result<()> {
        state.save(&self.path)
    }
}

// ---------------------------------------------------------------------------
// Session
// ---------------------------------------------------------------------------

/// Test-accuracy evaluator over the deployable parameters (training
/// sessions bind [`eval_accuracy`] over the model + test split; oracle
/// sessions may have none).
type Evaluator<'a> = Box<dyn FnMut(&[f32]) -> Result<f64> + 'a>;

/// One executed-but-not-yet-emitted iteration: everything `step()` learned
/// at issue time. `loss` is `NaN` while the round is still in flight on
/// the fabric (bounded-staleness pipelining); the fabric's completion
/// patches it in, and the step is emitted once it reaches the queue front.
#[derive(Debug, Clone, Copy)]
struct PendingStep {
    t: u64,
    /// mean train loss; `NaN` until the round completes
    loss: f64,
    recorded: bool,
    sync_round: bool,
    /// per-worker byte delta of this iteration (for [`SyncEvent`])
    sync_bytes: u64,
    /// per-worker scalar delta of this iteration (for [`SyncEvent`])
    sync_scalars: u64,
    do_eval: bool,
    final_step: bool,
}

/// One run as a first-class value: step it, observe it, snapshot it,
/// resume it. Generic over the [`Oracle`] (defaulting to the training
/// oracle); see the module docs for the contract. `run_train_with` is a
/// thin wrapper that drives a `Session` to completion.
pub struct Session<'a, O: Oracle = TrainOracle<'a>> {
    cfg: TrainConfig,
    world: World<O>,
    algo: Box<dyn Algorithm<O>>,
    recorder: TraceRecorder,
    observers: Vec<Box<dyn Observer + 'a>>,
    evaluator: Option<Evaluator<'a>>,
    /// next iteration to execute
    t: u64,
    /// executed iterations whose rounds may still be in flight on the
    /// fabric (FIFO; non-empty only at `staleness_window > 0`)
    pending: VecDeque<PendingStep>,
    watch: Stopwatch,
    /// out-of-band observability handle (disabled unless
    /// [`Session::set_telemetry`] attached one); never feeds the numeric path
    telemetry: Recorder,
    /// worker-side span collection armed ([`Session::set_trace`])
    trace_on: bool,
    /// worker span rings drained so far, in drain order (barrier points)
    trace_rings: Vec<DrainedRing>,
    eval_overhead: f64,
    /// compute seconds carried over from the run segment(s) before restore
    compute_base_s: f64,
    eval_buf: Vec<f32>,
}

impl<'a> Session<'a, TrainOracle<'a>> {
    /// Build a fresh training session at iteration 0 (sharding,
    /// initial-point broadcast, comm simulator, worker pool, transport
    /// fabric, algorithm instantiation).
    pub fn new(model: &'a dyn ModelBackend, data: &'a RunData, cfg: &TrainConfig) -> Result<Self> {
        cfg.validate()?;
        let oracle = TrainOracle::new(
            model,
            &data.train,
            cfg.workers,
            crate::coordinator::effective_redundancy(cfg),
            cfg.seed,
        );
        // the communication fabric: in-process loopback (with any
        // configured fault plan and staleness window) unless remote
        // daemons are configured
        let transport: Box<dyn Transport<TrainOracle<'a>>> =
            if cfg.transport.workers_at.is_empty() {
                Box::new(Loopback::with_window(
                    cfg.transport.fault.clone(),
                    cfg.transport.staleness_window,
                ))
            } else {
                Box::new(TcpTransport::connect(&cfg.transport.workers_at, cfg, model.dim())?)
            };
        // the worker execution engine: reuse the model's kernel pool so one
        // `--threads` knob governs the whole run; otherwise build one from
        // the config (traces are bit-identical at any thread count)
        let pool = model
            .pool()
            .unwrap_or_else(|| Arc::new(WorkerPool::new(resolve_threads(cfg.threads))));
        let test = &data.test;
        let evaluator: Evaluator<'a> =
            Box::new(move |params: &[f32]| eval_accuracy(model, params, test));
        Self::from_parts(oracle, cfg, pool, transport, Some(evaluator))
    }

    /// Rebuild a session from a snapshot so that stepping it to the
    /// horizon is bit-identical to never having stopped. `cfg` must
    /// describe the same run the snapshot came from; any divergence in a
    /// trajectory-affecting knob is rejected with a descriptive error.
    /// (The transport fabric and thread count are NOT part of the run
    /// identity — a TCP run may resume in-process and vice versa.)
    pub fn restore(
        model: &'a dyn ModelBackend,
        data: &'a RunData,
        cfg: &TrainConfig,
        state: RunState,
    ) -> Result<Self> {
        let expect = run_meta(cfg, model.dim());
        check_meta(&state.meta, &expect)?;
        if state.iter > cfg.iters {
            bail!(
                "checkpoint is at iteration {} but the run horizon is only {}",
                state.iter,
                cfg.iters
            );
        }
        let mut s = Self::new(model, data, cfg)?;
        s.load_state(state)?;
        Ok(s)
    }
}

impl<'a, O: Oracle> Session<'a, O> {
    /// Build a session over an arbitrary oracle — the embedding point for
    /// non-training objectives (the Section 5.1 attack drives its CW-loss
    /// oracle through this). The oracle's own `Loopback` fabric carries
    /// the rounds (any fault plan in `cfg` applies; `workers_at` is
    /// ignored — remote daemons rebuild *training* oracles only) and there
    /// is no test-set evaluator, so `eval_every` must be 0.
    pub fn with_oracle(oracle: O, cfg: &TrainConfig, pool: Arc<WorkerPool>) -> Result<Self> {
        cfg.validate()?;
        if cfg.eval_every > 0 {
            bail!(
                "Session::with_oracle has no test-set evaluator; set eval_every = 0 \
                 (or use Session::new for training runs)"
            );
        }
        let transport: Box<dyn Transport<O>> = Box::new(Loopback::with_window(
            cfg.transport.fault.clone(),
            cfg.transport.staleness_window,
        ));
        Self::from_parts(oracle, cfg, pool, transport, None)
    }

    fn from_parts(
        oracle: O,
        cfg: &TrainConfig,
        pool: Arc<WorkerPool>,
        transport: Box<dyn Transport<O>>,
        evaluator: Option<Evaluator<'a>>,
    ) -> Result<Self> {
        let acfg = AlgoConfig::from_train(cfg, oracle.dim());
        let init = oracle.init_params(crate::rng::SeedRegistry::new(cfg.seed).init_seed());
        let comm = CommSim::new(cfg.network, cfg.workers);
        let dim = oracle.dim();
        let world = World::with_transport(oracle, comm, acfg.clone(), pool, transport);
        let algo = build(cfg.method, init, &acfg);
        Ok(Self {
            cfg: cfg.clone(),
            world,
            algo,
            recorder: TraceRecorder::default(),
            observers: Vec::new(),
            evaluator,
            t: 0,
            pending: VecDeque::new(),
            watch: Stopwatch::start(),
            telemetry: Recorder::disabled(),
            trace_on: false,
            trace_rings: Vec::new(),
            eval_overhead: 0.0,
            compute_base_s: 0.0,
            eval_buf: Vec::with_capacity(dim),
        })
    }

    /// Attach a streaming observer (events fire for every subsequent step).
    pub fn add_observer(&mut self, obs: impl Observer + 'a) {
        self.observers.push(Box::new(obs));
    }

    /// Attach a telemetry [`Recorder`] to the session and everything under
    /// it (the transport fabric and the worker pool). Strictly out-of-band:
    /// attaching, detaching or dropping the recorder leaves the canonical
    /// trace byte-identical — spans and histograms observe the run, they
    /// never steer it.
    pub fn set_telemetry(&mut self, rec: Recorder) {
        self.world.instrument(rec.clone());
        self.telemetry = rec;
    }

    /// Arm worker-side span collection: the fabric records (or, on TCP,
    /// the remote daemons retain) per-`(rank, t)` spans, and the session
    /// drains their rings at every barrier point it already crosses (the
    /// eval cadence, snapshots, the end of the run). Out-of-band like
    /// [`Session::set_telemetry`]: arming, draining or discarding the
    /// collected spans leaves the canonical trace byte-identical.
    pub fn set_trace(&mut self, on: bool) {
        self.world.set_trace(on);
        self.trace_on = on;
    }

    /// Pull everything the fabric's worker rings hold right now into the
    /// session's accumulated trace. Only called with the pipeline drained.
    fn collect_trace(&mut self) -> Result<()> {
        if self.trace_on {
            self.trace_rings.extend(self.world.drain_trace()?);
        }
        Ok(())
    }

    /// Take the worker-side spans drained so far (a final flush + drain
    /// included), leaving the session's accumulator empty. Pair with the
    /// coordinator-side recorder's ring to build the merged timeline
    /// ([`crate::telemetry::trace::chrome_trace_json`]).
    pub fn take_trace(&mut self) -> Result<Vec<DrainedRing>> {
        // flush_pending ends with a collect_trace, so this is final
        let _ = self.flush_pending()?;
        Ok(std::mem::take(&mut self.trace_rings))
    }

    /// Next iteration to execute (= iterations completed so far).
    pub fn iter(&self) -> u64 {
        self.t
    }

    /// Has the full horizon `N` been executed?
    pub fn is_finished(&self) -> bool {
        self.t >= self.cfg.iters
    }

    /// The run configuration this session was built from.
    pub fn config(&self) -> &TrainConfig {
        &self.cfg
    }

    /// The active communication fabric (`"loopback"` / `"tcp"`).
    pub fn transport_label(&self) -> &'static str {
        self.world.transport_label()
    }

    /// Rows recorded so far (the in-progress trace).
    pub fn rows(&self) -> &[TraceRow] {
        &self.recorder.rows
    }

    /// Execute one iteration of the method's schedule and return the
    /// [`StepEvent`]s it *completed*. Errors once the horizon is
    /// exhausted.
    ///
    /// At staleness window `W = 0` (the default) every round completes
    /// synchronously: the returned vector holds exactly the one event for
    /// this iteration and observers fire inside this call — the classic
    /// contract, byte-identical traces included. At `W > 0` a pipelineable
    /// round (RI-SGD's local step between averaging points) may still be
    /// in flight when this returns: its event is emitted — in iteration
    /// order, with the documented observer dispatch order preserved — by
    /// whichever later call completes it (`step()`, the eval cadence, a
    /// snapshot, or the end of the run), so the vector may be empty or
    /// carry several events. A [`TraceRow`] is built when its round
    /// *completes*, so at `W > 0` its cumulative counters can include the
    /// issue-side cost of later in-flight rounds — honest accounting for
    /// an asynchronous schedule (and exactly the classic numbers at
    /// `W = 0`).
    pub fn step(&mut self) -> Result<Vec<StepEvent>> {
        let t = self.t;
        if t >= self.cfg.iters {
            bail!("session already ran all {} iterations", self.cfg.iters);
        }
        let before = self.world.comm.stats;
        let step_t0 = self.telemetry.start();
        let train_loss = self.algo.step(t, &mut self.world)?;
        self.telemetry.span("step", step_t0, vec![("t", Attr::U64(t))]);
        self.t = t + 1;

        let stats = self.world.comm.stats;
        // a vector-level exchange moves ≥ d scalars per worker; ZO rounds
        // move O(1) — the gap is the paper's whole point, so the
        // classification is unambiguous
        let d = self.world.dim() as u64;
        let last = self.t == self.cfg.iters;
        let record = self.cfg.record_every > 0 && t % self.cfg.record_every == 0;
        let do_eval = self.cfg.eval_every > 0 && (t % self.cfg.eval_every == 0 || last);
        self.pending.push_back(PendingStep {
            t,
            loss: train_loss,
            recorded: record || last || do_eval,
            sync_round: stats.scalars_per_worker - before.scalars_per_worker >= d,
            sync_bytes: stats.bytes_per_worker - before.bytes_per_worker,
            sync_scalars: stats.scalars_per_worker - before.scalars_per_worker,
            do_eval,
            final_step: last,
        });
        if do_eval || last {
            // evaluation (and run finish) reads post-step state: complete
            // everything still in flight first
            self.world.barrier()?;
            self.collect_trace()?;
        }
        let mut events = self.emit_ready()?;

        // snapshot-wanting observers (PeriodicCheckpoint and friends):
        // query each completed event in order; the RunState is built at
        // most once per event and shared among all askers. Building a
        // snapshot forces the pipeline dry — any rows completed by that
        // flush join this call's events and get their own query below.
        let mut i = 0;
        while i < events.len() {
            let ev = events[i];
            i += 1;
            let wants: Vec<bool> =
                self.observers.iter_mut().map(|o| o.wants_snapshot(&ev)).collect();
            if !wants.contains(&true) {
                continue;
            }
            events.extend(self.flush_pending()?);
            let state = self.build_run_state()?;
            // taken out so `on_snapshot` borrows no part of the session
            let mut obs = std::mem::take(&mut self.observers);
            let outcome = obs
                .iter_mut()
                .zip(&wants)
                .filter(|&(_, &w)| w)
                .try_for_each(|(o, _)| o.on_snapshot(&state));
            self.observers = obs;
            outcome?;
        }
        Ok(events)
    }

    /// Patch in losses the fabric has delivered since the last call, then
    /// emit every completed front-of-queue step. Rounds are FIFO per
    /// fabric, so completions drain the queue front-to-back and events
    /// fire in iteration order.
    fn emit_ready(&mut self) -> Result<Vec<StepEvent>> {
        for (ct, loss) in self.world.take_completions() {
            if let Some(p) = self.pending.iter_mut().find(|p| p.t == ct) {
                p.loss = loss;
            }
        }
        let mut events = Vec::new();
        while self.pending.front().is_some_and(|p| !p.loss.is_nan()) {
            let p = self.pending.pop_front().expect("front just checked");
            events.push(self.emit_one(p)?);
        }
        Ok(events)
    }

    /// Complete everything in flight and emit the whole pending queue.
    fn flush_pending(&mut self) -> Result<Vec<StepEvent>> {
        self.world.barrier()?;
        self.collect_trace()?;
        self.emit_ready()
    }

    /// Emit one completed step: evaluate if it is on the eval cadence,
    /// build its [`TraceRow`] from the now-current cumulative counters and
    /// fire the observer events in the documented order
    /// (`on_sync_round` → `on_eval` → `on_step`).
    fn emit_one(&mut self, p: PendingStep) -> Result<StepEvent> {
        // eval-cadence steps barrier inside their own `step()` call, so
        // the state read here is exactly the post-step state
        let test_acc = if p.do_eval { Some(self.eval_drained()?) } else { None };
        let stats = self.world.comm.stats;
        let compute_s =
            self.compute_base_s + (self.watch.elapsed_s() - self.eval_overhead).max(0.0);
        let comm_s = stats.sim_time_s;
        let ev = StepEvent {
            row: TraceRow {
                iter: p.t,
                train_loss: p.loss,
                test_acc,
                compute_s,
                comm_s,
                total_s: compute_s + comm_s,
                bytes_per_worker: stats.bytes_per_worker,
                scalars_per_worker: stats.scalars_per_worker,
                wire_up_bytes: stats.wire_up_bytes,
                wire_down_bytes: stats.wire_down_bytes,
                fn_evals: self.world.compute.fn_evals,
                grad_evals: self.world.compute.grad_evals,
            },
            recorded: p.recorded,
            sync_round: p.sync_round,
            final_step: p.final_step,
        };
        if p.sync_round {
            self.telemetry.event(
                "sync_round",
                vec![
                    ("t", Attr::U64(p.t)),
                    ("bytes", Attr::U64(p.sync_bytes)),
                    ("scalars", Attr::U64(p.sync_scalars)),
                ],
            );
            let sev = SyncEvent { iter: p.t, bytes: p.sync_bytes, scalars: p.sync_scalars };
            for obs in &mut self.observers {
                obs.on_sync_round(&sev);
            }
        }
        if let Some(accuracy) = test_acc {
            let eev = EvalEvent { iter: p.t, accuracy };
            for obs in &mut self.observers {
                obs.on_eval(&eev);
            }
        }
        self.recorder.on_step(&ev);
        for obs in &mut self.observers {
            obs.on_step(&ev);
        }
        Ok(ev)
    }

    /// Evaluate test accuracy with the pipeline already drained: pull any
    /// worker-resident optimizer state home
    /// ([`Algorithm::sync_state`]), then run the evaluator over the
    /// deployable parameters. Evaluation cost is excluded from the
    /// trace's compute axis.
    fn eval_drained(&mut self) -> Result<f64> {
        self.algo.sync_state(&mut self.world)?;
        let span_t0 = self.telemetry.start();
        let e0 = self.watch.elapsed_s();
        self.algo.eval_params(&mut self.eval_buf);
        let Some(evaluator) = self.evaluator.as_mut() else {
            bail!("this session has no test-set evaluator (built with Session::with_oracle)");
        };
        let acc = evaluator(&self.eval_buf)?;
        self.eval_overhead += self.watch.elapsed_s() - e0;
        self.telemetry.span("eval", span_t0, vec![("t", Attr::U64(self.t))]);
        Ok(acc)
    }

    /// Step until iteration `t` (exclusive) or the horizon, whichever is
    /// first. `run_until(k)` then `run_until(N)` is the interruptible
    /// spelling of `run_to_end`.
    pub fn run_until(&mut self, t: u64) -> Result<()> {
        let stop = t.min(self.cfg.iters);
        while self.t < stop {
            self.step()?;
        }
        Ok(())
    }

    /// Step through the remaining horizon.
    pub fn run_to_end(&mut self) -> Result<()> {
        self.run_until(self.cfg.iters)
    }

    /// Evaluate test accuracy of the current deployable parameters now
    /// (outside the `eval_every` cadence; the cost is excluded from the
    /// trace's compute axis like any other evaluation). A flush point:
    /// in-flight rounds complete (and their events fire) before the
    /// evaluation. Errors on sessions built without an evaluator
    /// ([`Session::with_oracle`]).
    pub fn eval_now(&mut self) -> Result<f64> {
        let _ = self.flush_pending()?;
        self.eval_drained()
    }

    /// Current deployable parameters (`Algorithm::eval_params`). A flush
    /// point: in-flight rounds complete and worker-resident optimizer
    /// state is pulled home first.
    pub fn params(&mut self) -> Result<Vec<f32>> {
        let _ = self.flush_pending()?;
        self.algo.sync_state(&mut self.world)?;
        self.algo.eval_params(&mut self.eval_buf);
        Ok(self.eval_buf.clone())
    }

    /// The trace recorded so far, with run metadata attached.
    pub fn trace(&self) -> Trace {
        Trace {
            method: self.cfg.method.label().to_string(),
            dataset: self.cfg.dataset.clone(),
            dim: self.world.dim(),
            workers: self.cfg.workers,
            batch: self.world.batch_size(),
            tau: self.cfg.tau,
            seed: self.cfg.seed,
            rows: self.recorder.rows.clone(),
        }
    }

    /// Finish the session into the classic `run_train_with` result. A
    /// flush point (see [`Session::snapshot`]).
    pub fn into_outcome(mut self) -> Result<TrainOutcome> {
        let _ = self.flush_pending()?;
        self.algo.sync_state(&mut self.world)?;
        let trace = self.trace();
        self.algo.eval_params(&mut self.eval_buf);
        Ok(TrainOutcome { trace, params: self.eval_buf })
    }

    // -- snapshot / restore -------------------------------------------------

    /// Capture the full resumable state (see [`RunState`]). A flush point:
    /// in-flight rounds complete first (their rows land in the trace and
    /// their events fire) and worker-resident optimizer state is pulled
    /// home, so the state is a consistent post-iteration cut. At `W = 0`
    /// this is cheap relative to an iteration: a few `O(d)` buffer copies.
    pub fn snapshot(&mut self) -> Result<RunState> {
        let _ = self.flush_pending()?;
        self.build_run_state()
    }

    /// Build the [`RunState`] with the pipeline already drained.
    fn build_run_state(&mut self) -> Result<RunState> {
        let span_t0 = self.telemetry.start();
        self.algo.sync_state(&mut self.world)?;
        self.algo.eval_params(&mut self.eval_buf);
        self.telemetry.span("snapshot", span_t0, vec![("t", Attr::U64(self.t))]);
        let compute_s =
            self.compute_base_s + (self.watch.elapsed_s() - self.eval_overhead).max(0.0);
        Ok(RunState {
            meta: run_meta(&self.cfg, self.world.dim()),
            iter: self.t,
            compute_s,
            comm: self.world.comm.stats,
            counters: self.world.compute,
            params: self.eval_buf.clone(),
            algo: self.algo.state(),
            rows: self.recorder.rows.clone(),
        })
    }

    /// Load a snapshot into this freshly built session (the tail of
    /// [`Session::restore`]; meta validation is the caller's job).
    fn load_state(&mut self, state: RunState) -> Result<()> {
        self.algo.load_state(state.algo)?;
        self.world.comm.restore_stats(state.comm);
        self.world.compute = state.counters;
        self.recorder.rows = state.rows;
        self.t = state.iter;
        self.compute_base_s = state.compute_s;
        Ok(())
    }
}

/// One u64 naming the exact trajectory + accounting `(cfg, dim)` drives:
/// a hash over every [`RunMeta`] identity field (the block the v2
/// checkpoint loader enforces field-by-field) including the embedded
/// `cfg_fingerprint` over the remaining trajectory-affecting knobs. Two
/// runs with equal fingerprints produce bit-identical canonical traces;
/// the sweep manifest keys completed runs by this value, which is why a
/// resumed sweep may trust a matching row instead of re-running.
pub fn run_fingerprint(cfg: &TrainConfig, dim: usize) -> u64 {
    let m = run_meta(cfg, dim);
    let hash_str = |s: &str| crate::coordinator::checkpoint::fnv1a(s.as_bytes());
    hash_u64s(&[
        hash_str(m.method.label()),
        hash_str(m.backend.label()),
        hash_str(&m.dataset),
        m.dim as u64,
        m.workers as u64,
        m.tau as u64,
        m.seed,
        m.iters,
        m.eval_every,
        m.record_every,
        m.mu_bits,
        m.cfg_fingerprint,
    ])
}

/// The identity block `Session::snapshot` stamps into a checkpoint.
fn run_meta(cfg: &TrainConfig, dim: usize) -> RunMeta {
    RunMeta {
        method: cfg.method,
        backend: cfg.backend,
        dataset: cfg.dataset.clone(),
        dim,
        workers: cfg.workers,
        tau: cfg.tau,
        seed: cfg.seed,
        iters: cfg.iters,
        eval_every: cfg.eval_every,
        record_every: cfg.record_every,
        mu_bits: cfg.resolve_mu(dim).to_bits(),
        cfg_fingerprint: cfg_fingerprint(cfg),
    }
}

/// Hash of the trajectory-affecting knobs not named in [`RunMeta`]: the
/// step-size rule, corpus sizes, RI-SGD redundancy, SVRG epoch geometry,
/// QSGD levels/EF, momentum, the network model, the fault-injection
/// plan (retries/latency enter the persisted wire counters, so a resumed
/// run must replay the identical plan), the loss-reduction
/// [`ComputeMode`](crate::backend::ComputeMode) (f32-mode losses differ
/// from f64-mode losses in the last bits, so their trajectories diverge
/// and must never share a checkpoint), and the staleness window (`W > 0`
/// changes *when* trace rows snapshot the cumulative counters — and, on
/// loopback, the simulated-time pipeline — so two windows do not share
/// accounting even though the parameter trajectory is unchanged). The
/// transport *fabric* is deliberately absent: at any fixed window,
/// loopback and TCP runs are byte-identical, so a checkpoint moves
/// freely between them. Two configs with equal meta and equal
/// fingerprint drive identical trajectories and accounting.
fn cfg_fingerprint(cfg: &TrainConfig) -> u64 {
    let step = match cfg.step {
        StepSize::Constant { alpha } => [1, alpha.to_bits(), 0],
        StepSize::InvDecay { alpha0, gamma } => [2, alpha0.to_bits(), gamma.to_bits()],
        StepSize::Theory { l_guess } => [3, l_guess.to_bits(), 0],
    };
    let fault = &cfg.transport.fault;
    let mut lat_parts: Vec<u64> = vec![fault.latency_s.len() as u64];
    lat_parts.extend(fault.latency_s.iter().map(|l| l.to_bits()));
    hash_u64s(&[
        step[0],
        step[1],
        step[2],
        cfg.train_size as u64,
        cfg.test_size as u64,
        cfg.redundancy.to_bits(),
        cfg.svrg_epoch as u64,
        cfg.svrg_probes as u64,
        cfg.qsgd_levels as u64,
        cfg.qsgd_error_feedback as u64,
        cfg.momentum.to_bits(),
        cfg.network.latency_s.to_bits(),
        cfg.network.bandwidth_bps.to_bits(),
        fault.drop_prob.to_bits(),
        fault.seed,
        hash_u64s(&lat_parts),
        cfg.compute as u64,
        cfg.transport.staleness_window as u64,
    ])
}

/// Field-by-field comparison with errors that name the offending knob.
fn check_meta(saved: &RunMeta, expect: &RunMeta) -> Result<()> {
    if saved.method != expect.method {
        bail!(
            "checkpoint was written by method {:?} but the run is configured for {:?}",
            saved.method.label(),
            expect.method.label()
        );
    }
    if saved.backend != expect.backend {
        bail!(
            "checkpoint was written under the {:?} backend but the run uses {:?} \
             (backends agree to tolerance, not bit-for-bit)",
            saved.backend.label(),
            expect.backend.label()
        );
    }
    if saved.dataset != expect.dataset {
        bail!(
            "checkpoint belongs to dataset {:?}, run is configured for {:?}",
            saved.dataset,
            expect.dataset
        );
    }
    if saved.dim != expect.dim {
        bail!("checkpoint dim {} does not match the model's {}", saved.dim, expect.dim);
    }
    if saved.workers != expect.workers {
        bail!("checkpoint has m = {} workers, run has {}", saved.workers, expect.workers);
    }
    if saved.tau != expect.tau {
        bail!("checkpoint has tau = {}, run has tau = {}", saved.tau, expect.tau);
    }
    if saved.seed != expect.seed {
        bail!("checkpoint seed {} does not match run seed {}", saved.seed, expect.seed);
    }
    if saved.iters != expect.iters {
        bail!(
            "checkpoint horizon N = {} does not match the run's N = {} \
             (step-size and mu schedules depend on N)",
            saved.iters,
            expect.iters
        );
    }
    if saved.eval_every != expect.eval_every || saved.record_every != expect.record_every {
        bail!(
            "checkpoint cadences (eval_every {}, record_every {}) do not match the \
             run's ({}, {}) — the resumed trace would not line up",
            saved.eval_every,
            saved.record_every,
            expect.eval_every,
            expect.record_every
        );
    }
    if saved.mu_bits != expect.mu_bits {
        bail!(
            "checkpoint smoothing mu = {} does not match the run's {}",
            f64::from_bits(saved.mu_bits),
            f64::from_bits(expect.mu_bits)
        );
    }
    if saved.cfg_fingerprint != expect.cfg_fingerprint {
        bail!(
            "checkpoint hyper-parameters differ from the run's (step rule, corpus \
             sizes, redundancy, SVRG/QSGD/momentum, network or fault-plan settings)"
        );
    }
    Ok(())
}
