//! Checkpointing substrate: persist/restore a flat model state (the `x^t`
//! of Algorithm 1) with an in-tree binary format.
//!
//! Format (little-endian): magic `HOSGDCK1` · u64 dim · u64 seed ·
//! u64 iter · dim×f32 payload · u64 FNV-1a checksum over everything
//! before it. Used by the attack driver (frozen classifier weights), the
//! e2e example (resume), and anything that wants to hand a trained model
//! to `ModelBackend::predict` on either backend.

use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

const MAGIC: &[u8; 8] = b"HOSGDCK1";

/// A saved model state.
#[derive(Debug, Clone, PartialEq)]
pub struct Checkpoint {
    pub params: Vec<f32>,
    pub seed: u64,
    pub iter: u64,
}

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

impl Checkpoint {
    pub fn new(params: Vec<f32>, seed: u64, iter: u64) -> Self {
        Self { params, seed, iter }
    }

    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(8 + 24 + 4 * self.params.len() + 8);
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&(self.params.len() as u64).to_le_bytes());
        out.extend_from_slice(&self.seed.to_le_bytes());
        out.extend_from_slice(&self.iter.to_le_bytes());
        for p in &self.params {
            out.extend_from_slice(&p.to_le_bytes());
        }
        let sum = fnv1a(&out);
        out.extend_from_slice(&sum.to_le_bytes());
        out
    }

    pub fn from_bytes(bytes: &[u8]) -> Result<Self> {
        if bytes.len() < 8 + 24 + 8 {
            bail!("checkpoint too short ({} bytes)", bytes.len());
        }
        if &bytes[0..8] != MAGIC {
            bail!("bad checkpoint magic");
        }
        let body = &bytes[..bytes.len() - 8];
        let stored = u64::from_le_bytes(bytes[bytes.len() - 8..].try_into()?);
        let computed = fnv1a(body);
        if stored != computed {
            bail!("checkpoint checksum mismatch (corrupt file)");
        }
        let u64_at = |off: usize| -> Result<u64> {
            Ok(u64::from_le_bytes(
                bytes[off..off + 8].try_into().map_err(|_| anyhow!("truncated"))?,
            ))
        };
        let dim = u64_at(8)? as usize;
        let seed = u64_at(16)?;
        let iter = u64_at(24)?;
        let payload = &bytes[32..bytes.len() - 8];
        if payload.len() != dim * 4 {
            bail!("checkpoint dim {dim} does not match payload {} bytes", payload.len());
        }
        let params = payload
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        Ok(Self { params, seed, iter })
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_bytes())
            .with_context(|| format!("writing checkpoint {}", path.display()))
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref();
        let bytes = std::fs::read(path)
            .with_context(|| format!("reading checkpoint {}", path.display()))?;
        Self::from_bytes(&bytes).with_context(|| format!("parsing {}", path.display()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ck() -> Checkpoint {
        Checkpoint::new((0..513).map(|i| i as f32 * 0.25 - 64.0).collect(), 42, 399)
    }

    #[test]
    fn roundtrip_bytes() {
        let c = ck();
        let back = Checkpoint::from_bytes(&c.to_bytes()).unwrap();
        assert_eq!(c, back);
    }

    #[test]
    fn roundtrip_file() {
        let c = ck();
        let dir = std::env::temp_dir().join("hosgd_ckpt_test");
        let path = dir.join("m.ckpt");
        c.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(c, back);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn detects_corruption() {
        let c = ck();
        let mut bytes = c.to_bytes();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        assert!(Checkpoint::from_bytes(&bytes).is_err());
    }

    #[test]
    fn rejects_bad_magic_and_short_input() {
        assert!(Checkpoint::from_bytes(b"short").is_err());
        let mut bytes = ck().to_bytes();
        bytes[0] = b'X';
        assert!(Checkpoint::from_bytes(&bytes).is_err());
    }

    #[test]
    fn rejects_dim_mismatch() {
        let c = ck();
        let mut bytes = c.to_bytes();
        // tamper with dim and refresh the checksum so only the dim check fires
        bytes[8..16].copy_from_slice(&(1u64).to_le_bytes());
        let body_len = bytes.len() - 8;
        let sum = super::fnv1a(&bytes[..body_len]);
        bytes[body_len..].copy_from_slice(&sum.to_le_bytes());
        assert!(Checkpoint::from_bytes(&bytes).is_err());
    }
}
