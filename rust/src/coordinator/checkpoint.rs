//! Checkpointing substrate: two in-tree binary formats.
//!
//! **v1 (`HOSGDCK1`)** — a flat model state (the `x^t` of Algorithm 1):
//! magic · u64 dim · u64 seed · u64 iter · dim×f32 payload · u64 FNV-1a
//! checksum over everything before it. Kept for the attack driver (frozen
//! classifier weights) and anything that only needs parameters to feed
//! `ModelBackend::predict`.
//!
//! **v2 (`HOSGDCK2`)** — a full training [`RunState`]: run identity
//! (method, dataset, dim, workers, τ, seed, N, cadences, resolved μ, a
//! fingerprint over the remaining trajectory-affecting hyper-parameters),
//! the iteration cursor, comm/compute accounting, the deployable parameter
//! view, every hidden optimizer buffer ([`AlgoState`]) and the trace rows
//! recorded so far. `Session::restore` resumes from it **bit-identically**:
//! the RNG needs no stored position because every stream is re-derived from
//! `(seed, iter, worker)`. The v2 loader rejects mismatched runs loudly;
//! [`load_params_any`] reads either version as params-only.
//!
//! NOTE: the v2 layout gained the transport fabric's measured wire
//! counters (`CommStats::wire_*`, plus two per-row fields) when the
//! communication subsystem landed. These are in-tree formats with no
//! cross-build compatibility promise; a file from an older build fails the
//! structural decode loudly rather than resuming with wrong counters.

use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::backend::BackendKind;
use crate::comm::CommStats;
use crate::config::Method;
use crate::metrics::{ComputeCounters, TraceRow};
use crate::optim::AlgoState;

const MAGIC: &[u8; 8] = b"HOSGDCK1";
const MAGIC_V2: &[u8; 8] = b"HOSGDCK2";

/// A saved model state.
#[derive(Debug, Clone, PartialEq)]
pub struct Checkpoint {
    pub params: Vec<f32>,
    pub seed: u64,
    pub iter: u64,
}

/// FNV-1a over raw bytes — the one checksum/string-hash primitive shared
/// by the checkpoint formats, the run fingerprint and the sweep manifest.
pub(crate) fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

impl Checkpoint {
    pub fn new(params: Vec<f32>, seed: u64, iter: u64) -> Self {
        Self { params, seed, iter }
    }

    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(8 + 24 + 4 * self.params.len() + 8);
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&(self.params.len() as u64).to_le_bytes());
        out.extend_from_slice(&self.seed.to_le_bytes());
        out.extend_from_slice(&self.iter.to_le_bytes());
        for p in &self.params {
            out.extend_from_slice(&p.to_le_bytes());
        }
        let sum = fnv1a(&out);
        out.extend_from_slice(&sum.to_le_bytes());
        out
    }

    pub fn from_bytes(bytes: &[u8]) -> Result<Self> {
        if bytes.len() < 8 + 24 + 8 {
            bail!("checkpoint too short ({} bytes)", bytes.len());
        }
        if &bytes[0..8] == MAGIC_V2 {
            bail!(
                "this is a v2 run-state checkpoint (HOSGDCK2); load it with \
                 RunState::load / Session::restore, or load_params_any for a \
                 params-only view"
            );
        }
        if &bytes[0..8] != MAGIC {
            bail!("bad checkpoint magic");
        }
        let body = &bytes[..bytes.len() - 8];
        let stored = u64::from_le_bytes(bytes[bytes.len() - 8..].try_into()?);
        let computed = fnv1a(body);
        if stored != computed {
            bail!("checkpoint checksum mismatch (corrupt file)");
        }
        let u64_at = |off: usize| -> Result<u64> {
            Ok(u64::from_le_bytes(
                bytes[off..off + 8].try_into().map_err(|_| anyhow!("truncated"))?,
            ))
        };
        let dim = u64_at(8)? as usize;
        let seed = u64_at(16)?;
        let iter = u64_at(24)?;
        let payload = &bytes[32..bytes.len() - 8];
        if payload.len() != dim * 4 {
            bail!("checkpoint dim {dim} does not match payload {} bytes", payload.len());
        }
        let params = payload
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        Ok(Self { params, seed, iter })
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_bytes())
            .with_context(|| format!("writing checkpoint {}", path.display()))
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref();
        let bytes = std::fs::read(path)
            .with_context(|| format!("reading checkpoint {}", path.display()))?;
        Self::from_bytes(&bytes).with_context(|| format!("parsing {}", path.display()))
    }
}

// ---------------------------------------------------------------------------
// v2: full run state (HOSGDCK2)
// ---------------------------------------------------------------------------

/// Identity of the run a v2 checkpoint belongs to. `Session::restore`
/// compares every field against the resuming configuration and refuses a
/// mismatch with a descriptive error — a resumed trajectory must be the
/// trajectory that was interrupted, never garbage.
#[derive(Debug, Clone, PartialEq)]
pub struct RunMeta {
    pub method: Method,
    /// compute backend the run executed on — native and pjrt kernels only
    /// agree to tolerance, not bit-for-bit, so resuming across backends is
    /// rejected
    pub backend: BackendKind,
    pub dataset: String,
    pub dim: usize,
    pub workers: usize,
    pub tau: usize,
    pub seed: u64,
    /// N — step-size schedules and the μ rule depend on the horizon
    pub iters: u64,
    /// row cadences: they shape the trace a resumed run must reproduce
    pub eval_every: u64,
    pub record_every: u64,
    /// resolved smoothing parameter μ, as f64 bits
    pub mu_bits: u64,
    /// hash over the remaining trajectory-affecting knobs (step rule,
    /// redundancy, SVRG/QSGD/momentum settings, corpus sizes, network)
    pub cfg_fingerprint: u64,
}

/// A complete, resumable snapshot of a training
/// [`Session`](crate::coordinator::session::Session) — everything needed to
/// continue the run bit-identically in a fresh process.
#[derive(Debug, Clone, PartialEq)]
pub struct RunState {
    pub meta: RunMeta,
    /// next iteration to execute (`iter` iterations are already applied)
    pub iter: u64,
    /// training compute seconds consumed so far (timing continuity only —
    /// excluded from canonical traces)
    pub compute_s: f64,
    pub comm: CommStats,
    pub counters: ComputeCounters,
    /// the deployable parameter view (`Algorithm::eval_params`) — what
    /// params-only consumers such as the attack driver read
    pub params: Vec<f32>,
    /// every hidden optimizer buffer, per method
    pub algo: AlgoState,
    /// trace rows recorded before the snapshot
    pub rows: Vec<TraceRow>,
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_str(out: &mut Vec<u8>, s: &str) {
    put_u64(out, s.len() as u64);
    out.extend_from_slice(s.as_bytes());
}

fn put_f32s(out: &mut Vec<u8>, xs: &[f32]) {
    put_u64(out, xs.len() as u64);
    for x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

/// Bounded little-endian reader over the checkpoint body.
struct Cursor<'a> {
    bytes: &'a [u8],
    off: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.bytes.len() < self.off + n {
            bail!("truncated checkpoint (wanted {n} bytes at offset {})", self.off);
        }
        let s = &self.bytes[self.off..self.off + n];
        self.off += n;
        Ok(s)
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_bits(self.u64()?))
    }

    fn str(&mut self) -> Result<String> {
        let n = self.u64()? as usize;
        if n > self.bytes.len() {
            bail!("checkpoint string length {n} exceeds file size");
        }
        let s = std::str::from_utf8(self.take(n)?)
            .map_err(|_| anyhow!("checkpoint string is not UTF-8"))?;
        Ok(s.to_string())
    }

    fn f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.u64()? as usize;
        if n.saturating_mul(4) > self.bytes.len() {
            bail!("checkpoint buffer length {n} exceeds file size");
        }
        let data = self
            .take(n * 4)?
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        Ok(data)
    }
}

impl RunState {
    pub fn to_bytes(&self) -> Vec<u8> {
        let algo_len: usize = self.algo.buffers.iter().map(|(n, b)| n.len() + 4 * b.len()).sum();
        let cap = 256 + 4 * self.params.len() + algo_len + self.rows.len() * TraceRow::ENCODED_LEN;
        let mut out = Vec::with_capacity(cap);
        out.extend_from_slice(MAGIC_V2);
        put_str(&mut out, self.meta.method.label());
        put_str(&mut out, self.meta.backend.label());
        put_str(&mut out, &self.meta.dataset);
        for v in [
            self.meta.dim as u64,
            self.meta.workers as u64,
            self.meta.tau as u64,
            self.meta.seed,
            self.meta.iters,
            self.meta.eval_every,
            self.meta.record_every,
            self.meta.mu_bits,
            self.meta.cfg_fingerprint,
            self.iter,
            self.compute_s.to_bits(),
            self.comm.bytes_per_worker,
            self.comm.scalars_per_worker,
            self.comm.rounds,
            self.comm.sim_time_s.to_bits(),
            self.comm.wire_up_bytes,
            self.comm.wire_down_bytes,
            self.comm.wire_frames,
            self.comm.wire_retries,
            self.counters.fn_evals,
            self.counters.grad_evals,
        ] {
            put_u64(&mut out, v);
        }
        put_f32s(&mut out, &self.params);
        put_u64(&mut out, self.algo.buffers.len() as u64);
        for (name, buf) in &self.algo.buffers {
            put_str(&mut out, name);
            put_f32s(&mut out, buf);
        }
        put_u64(&mut out, self.rows.len() as u64);
        for row in &self.rows {
            row.write_le(&mut out);
        }
        let sum = fnv1a(&out);
        out.extend_from_slice(&sum.to_le_bytes());
        out
    }

    pub fn from_bytes(bytes: &[u8]) -> Result<Self> {
        if bytes.len() < 8 + 8 {
            bail!("run-state checkpoint too short ({} bytes)", bytes.len());
        }
        if &bytes[0..8] == MAGIC {
            bail!(
                "this is a v1 params-only checkpoint (HOSGDCK1); it cannot resume \
                 a run — load it with Checkpoint::load (attack driver) or \
                 load_params_any"
            );
        }
        if &bytes[0..8] != MAGIC_V2 {
            bail!("bad run-state checkpoint magic");
        }
        let body = &bytes[..bytes.len() - 8];
        let stored = u64::from_le_bytes(bytes[bytes.len() - 8..].try_into()?);
        if stored != fnv1a(body) {
            bail!("run-state checkpoint checksum mismatch (corrupt file)");
        }
        let mut c = Cursor { bytes: body, off: 8 };
        let method: Method = c.str()?.parse()?;
        let backend: BackendKind = c.str()?.parse()?;
        let dataset = c.str()?;
        let meta = RunMeta {
            method,
            backend,
            dataset,
            dim: c.u64()? as usize,
            workers: c.u64()? as usize,
            tau: c.u64()? as usize,
            seed: c.u64()?,
            iters: c.u64()?,
            eval_every: c.u64()?,
            record_every: c.u64()?,
            mu_bits: c.u64()?,
            cfg_fingerprint: c.u64()?,
        };
        let iter = c.u64()?;
        let compute_s = c.f64()?;
        let comm = CommStats {
            bytes_per_worker: c.u64()?,
            scalars_per_worker: c.u64()?,
            rounds: c.u64()?,
            sim_time_s: c.f64()?,
            wire_up_bytes: c.u64()?,
            wire_down_bytes: c.u64()?,
            wire_frames: c.u64()?,
            wire_retries: c.u64()?,
        };
        let counters = ComputeCounters { fn_evals: c.u64()?, grad_evals: c.u64()? };
        let params = c.f32s()?;
        if params.len() != meta.dim {
            bail!(
                "run-state checkpoint dim {} does not match its parameter payload ({})",
                meta.dim,
                params.len()
            );
        }
        let n_bufs = c.u64()? as usize;
        let mut algo = AlgoState::new(method);
        for _ in 0..n_bufs {
            let name = c.str()?;
            let buf = c.f32s()?;
            algo = algo.with(name, buf);
        }
        let n_rows = c.u64()? as usize;
        if n_rows.saturating_mul(TraceRow::ENCODED_LEN) > body.len() {
            bail!("run-state checkpoint row count {n_rows} exceeds file size");
        }
        let mut rows = Vec::with_capacity(n_rows);
        for _ in 0..n_rows {
            rows.push(TraceRow::read_le(body, &mut c.off)?);
        }
        if c.off != body.len() {
            bail!("run-state checkpoint has {} trailing bytes", body.len() - c.off);
        }
        Ok(Self { meta, iter, compute_s, comm, counters, params, algo, rows })
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_bytes())
            .with_context(|| format!("writing run-state checkpoint {}", path.display()))
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref();
        let bytes = std::fs::read(path)
            .with_context(|| format!("reading run-state checkpoint {}", path.display()))?;
        Self::from_bytes(&bytes).with_context(|| format!("parsing {}", path.display()))
    }
}

/// Read either checkpoint version as a params-only [`Checkpoint`] — the
/// attack driver's view (it only needs frozen classifier weights). v1 files
/// load verbatim; v2 files contribute their deployable parameter view.
pub fn load_params_any(path: impl AsRef<Path>) -> Result<Checkpoint> {
    let path = path.as_ref();
    let bytes = std::fs::read(path)
        .with_context(|| format!("reading checkpoint {}", path.display()))?;
    if bytes.len() >= 8 && &bytes[0..8] == MAGIC_V2 {
        let st = RunState::from_bytes(&bytes)
            .with_context(|| format!("parsing {}", path.display()))?;
        return Ok(Checkpoint::new(st.params, st.meta.seed, st.iter));
    }
    Checkpoint::from_bytes(&bytes).with_context(|| format!("parsing {}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ck() -> Checkpoint {
        Checkpoint::new((0..513).map(|i| i as f32 * 0.25 - 64.0).collect(), 42, 399)
    }

    #[test]
    fn roundtrip_bytes() {
        let c = ck();
        let back = Checkpoint::from_bytes(&c.to_bytes()).unwrap();
        assert_eq!(c, back);
    }

    #[test]
    fn roundtrip_file() {
        let c = ck();
        let dir = std::env::temp_dir().join("hosgd_ckpt_test");
        let path = dir.join("m.ckpt");
        c.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(c, back);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn detects_corruption() {
        let c = ck();
        let mut bytes = c.to_bytes();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        assert!(Checkpoint::from_bytes(&bytes).is_err());
    }

    #[test]
    fn rejects_bad_magic_and_short_input() {
        assert!(Checkpoint::from_bytes(b"short").is_err());
        let mut bytes = ck().to_bytes();
        bytes[0] = b'X';
        assert!(Checkpoint::from_bytes(&bytes).is_err());
    }

    #[test]
    fn rejects_dim_mismatch() {
        let c = ck();
        let mut bytes = c.to_bytes();
        // tamper with dim and refresh the checksum so only the dim check fires
        bytes[8..16].copy_from_slice(&(1u64).to_le_bytes());
        let body_len = bytes.len() - 8;
        let sum = super::fnv1a(&bytes[..body_len]);
        bytes[body_len..].copy_from_slice(&sum.to_le_bytes());
        assert!(Checkpoint::from_bytes(&bytes).is_err());
    }

    fn run_state() -> RunState {
        RunState {
            meta: RunMeta {
                method: Method::HoSgdM,
                backend: BackendKind::Native,
                dataset: "quickstart".into(),
                dim: 3,
                workers: 4,
                tau: 8,
                seed: 11,
                iters: 100,
                eval_every: 10,
                record_every: 1,
                mu_bits: 0.01f64.to_bits(),
                cfg_fingerprint: 0xDEAD_BEEF,
            },
            iter: 42,
            compute_s: 1.25,
            comm: CommStats {
                bytes_per_worker: 1000,
                scalars_per_worker: 250,
                rounds: 42,
                sim_time_s: 0.123_456_789,
                wire_up_bytes: 1234,
                wire_down_bytes: 56_789,
                wire_frames: 126,
                wire_retries: 3,
            },
            counters: ComputeCounters { fn_evals: 640, grad_evals: 320 },
            params: vec![1.0, -2.0, 3.5],
            algo: AlgoState::new(Method::HoSgdM)
                .with("params", vec![1.0, -2.0, 3.5])
                .with("velocity", vec![0.1, 0.2, 0.3]),
            rows: vec![TraceRow {
                iter: 41,
                train_loss: 0.5,
                test_acc: Some(0.875),
                compute_s: 1.2,
                comm_s: 0.1,
                total_s: 1.3,
                bytes_per_worker: 1000,
                scalars_per_worker: 250,
                wire_up_bytes: 1234,
                wire_down_bytes: 56_789,
                fn_evals: 640,
                grad_evals: 320,
            }],
        }
    }

    #[test]
    fn v2_roundtrip_is_exact() {
        let st = run_state();
        let back = RunState::from_bytes(&st.to_bytes()).unwrap();
        assert_eq!(back, st);
        assert_eq!(back.comm.sim_time_s.to_bits(), st.comm.sim_time_s.to_bits());
        assert_eq!(back.rows[0].train_loss.to_bits(), st.rows[0].train_loss.to_bits());
    }

    #[test]
    fn v2_detects_corruption_and_rejects_v1() {
        let st = run_state();
        let mut bytes = st.to_bytes();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        assert!(RunState::from_bytes(&bytes).is_err());
        // a v1 file is refused with a pointed message, not misparsed
        let err = RunState::from_bytes(&ck().to_bytes()).unwrap_err();
        assert!(err.to_string().contains("v1"), "{err}");
        // and vice versa: the v1 loader names the v2 format
        let err = Checkpoint::from_bytes(&st.to_bytes()).unwrap_err();
        assert!(err.to_string().contains("v2"), "{err}");
    }

    #[test]
    fn load_params_any_reads_both_versions() {
        let dir = std::env::temp_dir().join("hosgd_ckpt_any_test");
        let v1 = dir.join("v1.ckpt");
        ck().save(&v1).unwrap();
        let got = load_params_any(&v1).unwrap();
        assert_eq!(got.params, ck().params);

        let st = run_state();
        let v2 = dir.join("v2.ck2");
        st.save(&v2).unwrap();
        let got = load_params_any(&v2).unwrap();
        assert_eq!(got.params, st.params);
        assert_eq!(got.seed, st.meta.seed);
        assert_eq!(got.iter, st.iter);
        std::fs::remove_dir_all(&dir).ok();
    }
}
