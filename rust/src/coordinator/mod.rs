//! The leader loop: drives `m` workers through N iterations of a chosen
//! method over a backend-bound model profile, producing a [`Trace`]. The
//! per-iteration worker fan-out runs on a [`crate::pool::WorkerPool`]
//! (`threads` in [`TrainConfig`] / `--threads` on the CLI) with a
//! fixed-order reduction, so traces are bit-identical at any thread count.
//!
//! Responsibilities: dataset materialization + sharding, initial-point
//! broadcast (all methods start from the same Glorot init — §5.2 "all the
//! methods are run from the same initial points"), the iteration schedule,
//! periodic test evaluation, wall-clock vs simulated-clock bookkeeping, and
//! trace recording. The model is an abstract [`ModelBackend`], so the same
//! loop runs against the native kernels or the PJRT artifacts.

pub mod checkpoint;

use std::sync::Arc;

use anyhow::Result;

use crate::backend::{Backend, ModelBackend};
use crate::comm::CommSim;
use crate::config::TrainConfig;
use crate::data::{profile, Dataset};
use crate::metrics::{Stopwatch, Trace, TraceRow};
use crate::optim::{build, AlgoConfig, Oracle, TrainOracle, World};
use crate::pool::{resolve_threads, WorkerPool};

/// Materialized datasets for one run.
pub struct RunData {
    pub train: Dataset,
    pub test: Dataset,
}

/// Generate the (synthetic) train/test corpora for a dataset profile.
pub fn make_data(cfg: &TrainConfig) -> Result<RunData> {
    let p = profile(&cfg.dataset)
        .ok_or_else(|| anyhow::anyhow!("no dataset profile named {:?}", cfg.dataset))?;
    let train_n = if cfg.train_size > 0 { cfg.train_size } else { p.train };
    let test_n = if cfg.test_size > 0 { cfg.test_size } else { p.test };
    // same mixture (split-independent class means), disjoint sample splits
    let train = Dataset::synth(&p, train_n, cfg.seed, 0);
    let test = Dataset::synth(&p, test_n, cfg.seed, 1);
    Ok(RunData { train, test })
}

/// Test-set accuracy of `params` over **all** samples: full model-batch
/// chunks go through the fused `accuracy` entry point; the tail remainder
/// (including test sets smaller than one batch) is zero-padded through
/// `predict` and scored on its real rows only. Rows of a dense forward
/// are independent, so padding cannot change the real rows' logits.
pub fn eval_accuracy(model: &dyn ModelBackend, params: &[f32], test: &Dataset) -> Result<f64> {
    let b = model.batch();
    let f = model.features();
    let classes = model.classes();
    let n = test.len();
    if n == 0 {
        return Ok(f64::NAN);
    }
    let chunks = n / b;
    let mut correct = 0.0f64;
    for c in 0..chunks {
        let x = &test.x[c * b * f..(c + 1) * b * f];
        let y = &test.y[c * b..(c + 1) * b];
        correct += model.accuracy(params, x, y)? as f64;
    }
    let tail = n - chunks * b;
    if tail > 0 {
        let mut xp = vec![0.0f32; b * f];
        xp[..tail * f].copy_from_slice(&test.x[chunks * b * f..]);
        let logits = model.predict(params, &xp)?;
        let y_tail = &test.y[chunks * b..];
        correct += (0..tail)
            .filter(|&k| {
                crate::backend::mlp::argmax(&logits[k * classes..(k + 1) * classes])
                    == y_tail[k] as usize
            })
            .count() as f64;
    }
    Ok(correct / n as f64)
}

/// A finished training run: the trace plus the final (deployable) model.
pub struct TrainOutcome {
    pub trace: Trace,
    pub params: Vec<f32>,
}

/// Run one full training experiment; returns the iteration trace.
pub fn run_train(backend: &dyn Backend, cfg: &TrainConfig) -> Result<Trace> {
    cfg.validate()?;
    let model = backend.model(&cfg.dataset)?;
    let data = make_data(cfg)?;
    Ok(run_train_with(model.as_ref(), &data, cfg)?.trace)
}

/// Same, with caller-provided model binding + datasets (lets sweeps share
/// bound models and corpora across methods).
pub fn run_train_with(
    model: &dyn ModelBackend,
    data: &RunData,
    cfg: &TrainConfig,
) -> Result<TrainOutcome> {
    cfg.validate()?;
    let acfg = AlgoConfig::from_train(cfg, model.dim());
    // RI-SGD samples from redundant pools; everyone else from iid shards
    let redundancy = if cfg.method == crate::config::Method::RiSgd {
        cfg.redundancy
    } else {
        0.0
    };
    let oracle = TrainOracle::new(model, &data.train, cfg.workers, redundancy, cfg.seed);
    let init = oracle.init_params(crate::rng::SeedRegistry::new(cfg.seed).init_seed());
    let comm = CommSim::new(cfg.network, cfg.workers);
    // the worker execution engine: reuse the model's kernel pool so one
    // `--threads` knob governs the whole run; otherwise build one from the
    // config (traces are bit-identical at any thread count either way)
    let pool = model
        .pool()
        .unwrap_or_else(|| Arc::new(WorkerPool::new(resolve_threads(cfg.threads))));
    let mut world = World::with_pool(oracle, comm, acfg.clone(), pool);
    let mut algo = build(cfg.method, init, &acfg);

    let mut rows = Vec::with_capacity((cfg.iters / cfg.record_every.max(1)) as usize + 2);
    let mut eval_buf = Vec::with_capacity(model.dim());
    let watch = Stopwatch::start();
    let mut eval_overhead = 0.0f64; // test evals are not training compute

    for t in 0..cfg.iters {
        let train_loss = algo.step(t, &mut world)?;

        let record = cfg.record_every > 0 && t % cfg.record_every.max(1) == 0;
        let last = t + 1 == cfg.iters;
        let do_eval = cfg.eval_every > 0 && (t % cfg.eval_every == 0 || last);
        if record || last || do_eval {
            let test_acc = if do_eval {
                let e0 = watch.elapsed_s();
                algo.eval_params(&mut eval_buf);
                let acc = eval_accuracy(model, &eval_buf, &data.test)?;
                eval_overhead += watch.elapsed_s() - e0;
                Some(acc)
            } else {
                None
            };
            let compute_s = (watch.elapsed_s() - eval_overhead).max(0.0);
            let comm_s = world.comm.stats.sim_time_s;
            rows.push(TraceRow {
                iter: t,
                train_loss,
                test_acc,
                compute_s,
                comm_s,
                total_s: compute_s + comm_s,
                bytes_per_worker: world.comm.stats.bytes_per_worker,
                scalars_per_worker: world.comm.stats.scalars_per_worker,
                fn_evals: world.compute.fn_evals,
                grad_evals: world.compute.grad_evals,
            });
        }
    }

    algo.eval_params(&mut eval_buf);
    Ok(TrainOutcome {
        trace: Trace {
            method: cfg.method.label().to_string(),
            dataset: cfg.dataset.clone(),
            dim: model.dim(),
            workers: cfg.workers,
            batch: model.batch(),
            tau: cfg.tau,
            seed: cfg.seed,
            rows,
        },
        params: eval_buf,
    })
}
