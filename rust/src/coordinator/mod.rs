//! The training driver layer: a first-class [`session::Session`] drives
//! `m` workers through the iteration schedule of a chosen method over a
//! backend-bound model profile — steppable ([`session::Session::step`]),
//! observable ([`session::Observer`]) and resumable
//! ([`session::Session::snapshot`] / [`session::Session::restore`] via the
//! v2 [`checkpoint::RunState`] format). The per-iteration worker fan-out
//! runs on a [`crate::pool::WorkerPool`] (`threads` in [`TrainConfig`] /
//! `--threads` on the CLI) with a fixed-order reduction, so traces are
//! bit-identical at any thread count — including across an
//! interrupt/resume boundary.
//!
//! Responsibilities: dataset materialization + sharding, initial-point
//! broadcast (all methods start from the same Glorot init — §5.2 "all the
//! methods are run from the same initial points"), the iteration schedule,
//! periodic test evaluation, wall-clock vs simulated-clock bookkeeping, and
//! trace recording. The model is an abstract [`ModelBackend`], so the same
//! loop runs against the native kernels or the PJRT artifacts.
//!
//! [`run_train`] / [`run_train_with`] remain as thin batch wrappers over
//! `Session` for callers that want one call → one finished [`Trace`]
//! (sweeps, benches, figures); new embedders should prefer `Session`.

pub mod checkpoint;
pub mod session;

use anyhow::Result;

use crate::backend::{Backend, ModelBackend};
use crate::config::TrainConfig;
use crate::data::{profile, Dataset};
use crate::metrics::Trace;

pub use session::{
    run_fingerprint, EvalEvent, Observer, PeriodicCheckpoint, Session, StepEvent, SyncEvent,
    TraceRecorder,
};

/// The data-redundancy a run's oracle sharding actually uses: RI-SGD
/// samples from overlapping pools (the μ_r of Haddadpour et al.), every
/// other method from disjoint iid shards. One function so the coordinator
/// and a remote `hosgd worker` daemon derive the identical sharding from
/// the shipped config.
pub fn effective_redundancy(cfg: &TrainConfig) -> f64 {
    if cfg.method == crate::config::Method::RiSgd {
        cfg.redundancy
    } else {
        0.0
    }
}

/// Materialized datasets for one run.
pub struct RunData {
    pub train: Dataset,
    pub test: Dataset,
}

/// Generate the (synthetic) train/test corpora for a dataset profile.
pub fn make_data(cfg: &TrainConfig) -> Result<RunData> {
    let p = profile(&cfg.dataset)
        .ok_or_else(|| anyhow::anyhow!("no dataset profile named {:?}", cfg.dataset))?;
    let train_n = if cfg.train_size > 0 { cfg.train_size } else { p.train };
    let test_n = if cfg.test_size > 0 { cfg.test_size } else { p.test };
    // same mixture (split-independent class means), disjoint sample splits
    let train = Dataset::synth(&p, train_n, cfg.seed, 0);
    let test = Dataset::synth(&p, test_n, cfg.seed, 1);
    Ok(RunData { train, test })
}

/// Test-set accuracy of `params` over **all** samples: full model-batch
/// chunks go through the fused `accuracy` entry point; the tail remainder
/// (including test sets smaller than one batch) is zero-padded through
/// `predict` and scored on its real rows only. Rows of a dense forward
/// are independent, so padding cannot change the real rows' logits.
///
/// An empty test set is an error: accuracy is undefined there, and the
/// previous `NaN` return silently poisoned traces and CSV output.
pub fn eval_accuracy(model: &dyn ModelBackend, params: &[f32], test: &Dataset) -> Result<f64> {
    let b = model.batch();
    let f = model.features();
    let classes = model.classes();
    let n = test.len();
    if n == 0 {
        anyhow::bail!("eval_accuracy: empty test set (accuracy is undefined over 0 samples)");
    }
    let chunks = n / b;
    let mut correct = 0.0f64;
    for c in 0..chunks {
        let x = &test.x[c * b * f..(c + 1) * b * f];
        let y = &test.y[c * b..(c + 1) * b];
        correct += model.accuracy(params, x, y)? as f64;
    }
    let tail = n - chunks * b;
    if tail > 0 {
        let mut xp = vec![0.0f32; b * f];
        xp[..tail * f].copy_from_slice(&test.x[chunks * b * f..]);
        let logits = model.predict(params, &xp)?;
        let y_tail = &test.y[chunks * b..];
        correct += (0..tail)
            .filter(|&k| {
                crate::backend::mlp::argmax(&logits[k * classes..(k + 1) * classes])
                    == y_tail[k] as usize
            })
            .count() as f64;
    }
    Ok(correct / n as f64)
}

/// A finished training run: the trace plus the final (deployable) model.
pub struct TrainOutcome {
    pub trace: Trace,
    pub params: Vec<f32>,
}

/// Run one full training experiment; returns the iteration trace.
///
/// Batch wrapper over [`Session`] — prefer `Session` when you need
/// stepping, streaming observers or checkpoint/resume.
pub fn run_train(backend: &dyn Backend, cfg: &TrainConfig) -> Result<Trace> {
    cfg.validate()?;
    let model = backend.model(&cfg.dataset)?;
    let data = make_data(cfg)?;
    Ok(run_train_with(model.as_ref(), &data, cfg)?.trace)
}

/// Same, with caller-provided model binding + datasets (lets sweeps share
/// bound models and corpora across methods). Thin wrapper over
/// [`Session`]: build, run to the horizon, hand back the outcome.
pub fn run_train_with(
    model: &dyn ModelBackend,
    data: &RunData,
    cfg: &TrainConfig,
) -> Result<TrainOutcome> {
    let mut session = Session::new(model, data, cfg)?;
    session.run_to_end()?;
    session.into_outcome()
}
