//! Vendored minimal reimplementation of the `anyhow` error-handling API.
//!
//! The build environment is fully offline, so instead of resolving the
//! published crate from crates.io this in-tree copy provides the exact API
//! surface the workspace uses: [`Error`], [`Result`], the [`anyhow!`] and
//! [`bail!`] macros, and the [`Context`] extension trait. Error values
//! carry a context chain that renders in `Debug` the same way anyhow's
//! does (`Caused by:` sections), so `fn main() -> anyhow::Result<()>`
//! failures stay readable.
//!
//! Swapping back to the published crate is a one-line change in
//! `rust/Cargo.toml`; no call site depends on anything beyond the shared
//! surface.

use std::error::Error as StdError;
use std::fmt;

/// A dynamic error with a chain of human-readable context frames.
pub struct Error {
    msg: String,
    cause: Option<Box<Error>>,
}

/// `Result<T, anyhow::Error>` with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Self { msg: message.to_string(), cause: None }
    }

    /// Wrap this error in an outer context frame.
    pub fn context<C: fmt::Display>(self, context: C) -> Self {
        Self { msg: context.to_string(), cause: Some(Box::new(self)) }
    }

    fn from_std<E: StdError + ?Sized>(err: &E) -> Self {
        let mut frames = vec![err.to_string()];
        let mut src = err.source();
        while let Some(s) = src {
            frames.push(s.to_string());
            src = s.source();
        }
        let mut out: Option<Error> = None;
        for msg in frames.into_iter().rev() {
            out = Some(Error { msg, cause: out.map(Box::new) });
        }
        out.expect("at least one frame")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        if f.alternate() {
            let mut cause = self.cause.as_deref();
            while let Some(c) = cause {
                write!(f, ": {}", c.msg)?;
                cause = c.cause.as_deref();
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        let mut cause = self.cause.as_deref();
        if cause.is_some() {
            write!(f, "\n\nCaused by:")?;
        }
        while let Some(c) = cause {
            write!(f, "\n    {}", c.msg)?;
            cause = c.cause.as_deref();
        }
        Ok(())
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(err: E) -> Self {
        Error::from_std(&err)
    }
}

/// Extension trait adding `.context(..)` / `.with_context(|| ..)`.
pub trait Context<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T>;
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string (inline captures work).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`anyhow!`] error.
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

/// Return early with an error if a condition is not satisfied.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($t:tt)*) => {
        if !$cond {
            return Err($crate::anyhow!($($t)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn macro_forms() {
        let a = anyhow!("plain");
        assert_eq!(a.to_string(), "plain");
        let n = 3;
        let b = anyhow!("n = {n}");
        assert_eq!(b.to_string(), "n = 3");
        let c = anyhow!("n = {}", n);
        assert_eq!(c.to_string(), "n = 3");
    }

    #[test]
    fn bail_returns_error() {
        fn f(x: u32) -> Result<u32> {
            if x == 0 {
                bail!("zero: {x}");
            }
            Ok(x)
        }
        assert_eq!(f(2).unwrap(), 2);
        assert_eq!(f(0).unwrap_err().to_string(), "zero: 0");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert_eq!(f().unwrap_err().to_string(), "gone");
    }

    #[test]
    fn context_chains_render_in_debug() {
        let e: Result<()> = Err(io_err());
        let e = e.context("reading config").unwrap_err();
        assert_eq!(e.to_string(), "reading config");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by:"), "{dbg}");
        assert!(dbg.contains("gone"), "{dbg}");
    }

    #[test]
    fn with_context_on_anyhow_result_and_option() {
        let r: Result<()> = Err(anyhow!("inner"));
        let e = r.with_context(|| format!("outer {}", 1)).unwrap_err();
        assert_eq!(e.to_string(), "outer 1");
        assert_eq!(format!("{e:#}"), "outer 1: inner");
        let o: Option<u8> = None;
        assert_eq!(o.context("absent").unwrap_err().to_string(), "absent");
    }

    #[test]
    fn ensure_checks_condition() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x > 1, "too small: {x}");
            Ok(x)
        }
        assert!(f(2).is_ok());
        assert_eq!(f(0).unwrap_err().to_string(), "too small: 0");
    }
}
