//! Type-level stand-in for the published `xla` crate (the PJRT C-API
//! bridge), so the `pjrt` feature of `hosgd` can be type-checked and
//! clippy/fmt-gated in CI on machines with no PJRT/XLA libraries and no
//! crates.io access.
//!
//! Every constructor that would touch PJRT returns [`Error::Stub`]; the
//! `hosgd` runtime surfaces that as "built against the xla stub" the moment
//! a PJRT client is requested, long before any compute. To run the real
//! backend, replace the dependency in `rust/Cargo.toml` with the published
//! crate (same module-level API):
//!
//! ```toml
//! xla = { version = "0.1.6", optional = true }
//! ```

use std::fmt;
use std::path::Path;

/// Error type mirroring `xla::Error`'s role in signatures.
#[derive(Debug)]
pub enum Error {
    /// Raised by every stub entry point.
    Stub,
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(
            "hosgd was built against the vendored xla stub; point the `xla` \
             dependency at the published crate to use the pjrt backend",
        )
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Host-side literal (stub: carries no data).
#[derive(Clone, Default)]
pub struct Literal;

impl Literal {
    pub fn vec1(_values: &[f32]) -> Self {
        Literal
    }

    pub fn scalar(_value: f32) -> Self {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(Error::Stub)
    }

    pub fn to_tuple1(&self) -> Result<Literal> {
        Err(Error::Stub)
    }

    pub fn to_tuple2(&self) -> Result<(Literal, Literal)> {
        Err(Error::Stub)
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error::Stub)
    }
}

/// Parsed HLO module (stub).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<Self> {
        Err(Error::Stub)
    }
}

/// XLA computation handle (stub).
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation
    }
}

/// Device buffer handle (stub).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::Stub)
    }
}

/// Compiled executable handle (stub).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute(&self, _args: &[Literal]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::Stub)
    }
}

/// PJRT client handle (stub: construction always fails).
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        Err(Error::Stub)
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::Stub)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_construction_fails_loudly() {
        let err = PjRtClient::cpu().err().expect("stub must fail");
        assert!(err.to_string().contains("xla stub"));
    }

    #[test]
    fn literal_constructors_are_callable() {
        let l = Literal::vec1(&[1.0, 2.0]);
        assert!(l.reshape(&[2, 1]).is_err());
        assert!(Literal::scalar(1.0).to_vec::<f32>().is_err());
    }
}
