"""L1 correctness: Pallas kernels vs the pure-jnp oracles in kernels/ref.py.

Hypothesis sweeps shapes and values; every case asserts allclose between the
interpret-mode Pallas path and the oracle. This is the CORE correctness
signal for the compute hot-spot.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed (pip install hypothesis)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from compile.kernels import ref as R
from compile.kernels.dense import (
    DEFAULT_BLOCK_B,
    DEFAULT_BLOCK_H,
    VMEM_BUDGET_BYTES,
    dense_linear,
    dense_relu,
    dense_shapes_ok,
    vmem_footprint_bytes,
)
from compile.kernels.zo import PERTURB_BLOCK, perturb

_SETTINGS = dict(max_examples=25, deadline=None)


def _arr(rng, shape, scale=1.0):
    return jnp.asarray(rng.normal(size=shape, scale=scale).astype(np.float32))


# ---------------------------------------------------------------------------
# dense kernels
# ---------------------------------------------------------------------------


@settings(**_SETTINGS)
@given(
    batch=st.integers(1, 140),
    features=st.integers(1, 70),
    out=st.integers(1, 140),
    seed=st.integers(0, 2**31 - 1),
)
def test_dense_relu_matches_ref(batch, features, out, seed):
    rng = np.random.default_rng(seed)
    x, w, b = _arr(rng, (batch, features)), _arr(rng, (features, out)), _arr(rng, (out,))
    np.testing.assert_allclose(
        np.asarray(dense_relu(x, w, b)),
        np.asarray(R.dense_relu_ref(x, w, b)),
        rtol=1e-5, atol=1e-5)


@settings(**_SETTINGS)
@given(
    batch=st.integers(1, 140),
    features=st.integers(1, 70),
    out=st.integers(1, 140),
    seed=st.integers(0, 2**31 - 1),
)
def test_dense_linear_matches_ref(batch, features, out, seed):
    rng = np.random.default_rng(seed)
    x, w, b = _arr(rng, (batch, features)), _arr(rng, (features, out)), _arr(rng, (out,))
    np.testing.assert_allclose(
        np.asarray(dense_linear(x, w, b)),
        np.asarray(R.dense_linear_ref(x, w, b)),
        rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("batch,features,out", [
    (64, 48, 128),    # sensorless first layer
    (64, 128, 11),    # sensorless head
    (64, 900, 64),    # attack classifier first layer
    (128, 128, 128),  # exact block boundary
    (129, 128, 129),  # one past the block boundary
    (1, 1, 1),        # degenerate
])
def test_dense_profile_shapes(batch, features, out):
    rng = np.random.default_rng(42)
    x, w, b = _arr(rng, (batch, features)), _arr(rng, (features, out)), _arr(rng, (out,))
    np.testing.assert_allclose(
        np.asarray(dense_relu(x, w, b)),
        np.asarray(R.dense_relu_ref(x, w, b)),
        rtol=1e-5, atol=1e-5)


def test_dense_relu_grad_matches_oracle_grad():
    """custom_vjp backward == autodiff through the oracle."""
    rng = np.random.default_rng(7)
    x, w, b = _arr(rng, (9, 5)), _arr(rng, (5, 11)), _arr(rng, (11,))

    def f_pallas(x, w, b):
        return jnp.sum(dense_relu(x, w, b) ** 2)

    def f_ref(x, w, b):
        return jnp.sum(R.dense_relu_ref(x, w, b) ** 2)

    gp = jax.grad(f_pallas, argnums=(0, 1, 2))(x, w, b)
    gr = jax.grad(f_ref, argnums=(0, 1, 2))(x, w, b)
    for a, c in zip(gp, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(c),
                                   rtol=1e-4, atol=1e-5)


def test_dense_linear_grad_matches_oracle_grad():
    rng = np.random.default_rng(8)
    x, w, b = _arr(rng, (6, 4)), _arr(rng, (4, 3)), _arr(rng, (3,))

    def f_pallas(x, w, b):
        return jnp.sum(jnp.sin(dense_linear(x, w, b)))

    def f_ref(x, w, b):
        return jnp.sum(jnp.sin(R.dense_linear_ref(x, w, b)))

    gp = jax.grad(f_pallas, argnums=(0, 1, 2))(x, w, b)
    gr = jax.grad(f_ref, argnums=(0, 1, 2))(x, w, b)
    for a, c in zip(gp, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(c),
                                   rtol=1e-4, atol=1e-5)


def test_vmem_budget_for_all_shipped_profiles():
    """Every dense layer in every AOT profile must fit the VMEM budget."""
    from compile.aot import PROFILES, spec_of
    for name, (_, _, _, _, batch) in PROFILES.items():
        s = spec_of(name)
        layers = [(batch, s.features, s.hidden1),
                  (batch, s.hidden1, s.hidden2),
                  (batch, s.hidden2, s.classes)]
        for (bb, f, o) in layers:
            ok, fp = dense_shapes_ok(bb, f, o)
            assert ok, f"{name} layer ({bb},{f},{o}) VMEM {fp} > budget"


def test_vmem_footprint_monotone_in_features():
    fps = [vmem_footprint_bytes(64, f, 128) for f in (16, 64, 256, 1024)]
    assert fps == sorted(fps)
    assert all(fp <= VMEM_BUDGET_BYTES for fp in fps)


def test_block_defaults_are_mxu_aligned():
    assert DEFAULT_BLOCK_B % 128 == 0
    assert DEFAULT_BLOCK_H % 128 == 0


# ---------------------------------------------------------------------------
# zo perturb kernel
# ---------------------------------------------------------------------------


@settings(**_SETTINGS)
@given(
    d=st.integers(1, 3 * PERTURB_BLOCK + 5),
    mu=st.floats(-1.0, 1.0, allow_nan=False, width=32),
    seed=st.integers(0, 2**31 - 1),
)
def test_perturb_matches_ref(d, mu, seed):
    rng = np.random.default_rng(seed)
    p, v = _arr(rng, (d,)), _arr(rng, (d,))
    mu = jnp.float32(mu)
    np.testing.assert_allclose(
        np.asarray(perturb(p, v, mu)),
        np.asarray(R.perturb_ref(p, v, mu)),
        rtol=1e-6, atol=1e-6)


def test_perturb_zero_mu_is_identity():
    rng = np.random.default_rng(3)
    p, v = _arr(rng, (1000,)), _arr(rng, (1000,))
    np.testing.assert_array_equal(
        np.asarray(perturb(p, v, jnp.float32(0.0))), np.asarray(p))


def test_perturb_grad():
    rng = np.random.default_rng(4)
    p, v = _arr(rng, (50,)), _arr(rng, (50,))
    mu = jnp.float32(0.3)

    def f(p, v, mu):
        return jnp.sum(perturb(p, v, mu) ** 2)

    gp, gv, gmu = jax.grad(f, argnums=(0, 1, 2))(p, v, mu)
    out = p + 0.3 * v
    np.testing.assert_allclose(np.asarray(gp), np.asarray(2 * out), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(gv), np.asarray(2 * 0.3 * out), rtol=1e-5)
    np.testing.assert_allclose(float(gmu), float(jnp.sum(2 * out * v)), rtol=1e-4)


# ---------------------------------------------------------------------------
# fused softmax cross-entropy kernel
# ---------------------------------------------------------------------------

from compile.kernels.softmax import BLOCK_B, softmax_xent  # noqa: E402


@settings(**_SETTINGS)
@given(
    batch=st.integers(1, 2 * 128 + 7),
    classes=st.integers(2, 16),
    seed=st.integers(0, 2**31 - 1),
)
def test_softmax_xent_matches_ref(batch, classes, seed):
    rng = np.random.default_rng(seed)
    logits = _arr(rng, (batch, classes), scale=3.0)
    y = jnp.asarray(rng.integers(0, classes, size=batch).astype(np.float32))
    got = float(softmax_xent(logits, y))
    want = float(R.softmax_xent_ref(logits, y.astype(jnp.int32)))
    assert abs(got - want) < 1e-5 * max(1.0, abs(want)), (got, want)


def test_softmax_xent_block_boundary_shapes():
    rng = np.random.default_rng(1)
    for batch in [BLOCK_B - 1, BLOCK_B, BLOCK_B + 1, 2 * BLOCK_B]:
        logits = _arr(rng, (batch, 5))
        y = jnp.asarray(rng.integers(0, 5, size=batch).astype(np.float32))
        got = float(softmax_xent(logits, y))
        want = float(R.softmax_xent_ref(logits, y.astype(jnp.int32)))
        assert abs(got - want) < 1e-5


def test_softmax_xent_grad_matches_oracle():
    rng = np.random.default_rng(2)
    logits = _arr(rng, (12, 7), scale=2.0)
    y = jnp.asarray(rng.integers(0, 7, size=12).astype(np.float32))
    gp = jax.grad(lambda l: softmax_xent(l, y))(logits)
    gr = jax.grad(lambda l: R.softmax_xent_ref(l, y.astype(jnp.int32)))(logits)
    np.testing.assert_allclose(np.asarray(gp), np.asarray(gr), rtol=1e-4, atol=1e-6)


def test_softmax_xent_numerical_stability_large_logits():
    # row max subtraction must keep exp() finite for huge logits
    logits = jnp.asarray([[1000.0, 0.0, -1000.0], [500.0, 499.0, -2.0]], jnp.float32)
    y = jnp.asarray([0.0, 1.0], jnp.float32)
    val = float(softmax_xent(logits, y))
    assert np.isfinite(val)
    assert val < 2.0  # both rows pick (near-)argmax labels
