"""L2 correctness: the JAX model graphs vs the kernel-free oracle model,
plus structural/mathematical properties of every AOT entry point
(ZO-estimator consistency, CW attack-loss properties, numerical gradients).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed (pip install hypothesis)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from compile import model as M
from compile.kernels import ref as R

SPEC = M.MLPSpec(features=10, hidden1=16, hidden2=16, classes=3)
BATCH = 8

_SETTINGS = dict(max_examples=15, deadline=None)


def _inputs(seed, spec=SPEC, batch=BATCH, scale=0.3):
    rng = np.random.default_rng(seed)
    p = jnp.asarray(rng.normal(size=spec.dim, scale=scale).astype(np.float32))
    x = jnp.asarray(rng.normal(size=(batch, spec.features)).astype(np.float32))
    y = jnp.asarray((rng.integers(0, spec.classes, size=batch)).astype(np.float32))
    return p, x, y


def _unit_dir(seed, d):
    rng = np.random.default_rng(seed)
    v = rng.normal(size=d)
    return jnp.asarray((v / np.linalg.norm(v)).astype(np.float32))


# ---------------------------------------------------------------------------
# spec / layout
# ---------------------------------------------------------------------------


@given(f=st.integers(1, 40), h1=st.integers(1, 40), h2=st.integers(1, 40),
       c=st.integers(2, 12))
@settings(**_SETTINGS)
def test_dim_matches_shapes(f, h1, h2, c):
    s = M.MLPSpec(f, h1, h2, c)
    total = sum(int(np.prod(shp)) for shp in s.shapes())
    assert s.dim == total


def test_unflatten_roundtrip():
    p, _, _ = _inputs(0)
    parts = M.unflatten(SPEC, p)
    flat = jnp.concatenate([t.reshape(-1) for t in parts])
    np.testing.assert_array_equal(np.asarray(flat), np.asarray(p))


def test_unflatten_shapes():
    p, _, _ = _inputs(1)
    shapes = tuple(t.shape for t in M.unflatten(SPEC, p))
    assert shapes == SPEC.shapes()


# ---------------------------------------------------------------------------
# pallas model vs oracle model
# ---------------------------------------------------------------------------


@given(seed=st.integers(0, 2**31 - 1))
@settings(**_SETTINGS)
def test_logits_match_oracle(seed):
    p, x, _ = _inputs(seed)
    np.testing.assert_allclose(
        np.asarray(M.logits(SPEC, p, x)),
        np.asarray(M.logits_oracle(SPEC, p, x)),
        rtol=1e-5, atol=1e-5)


@given(seed=st.integers(0, 2**31 - 1))
@settings(**_SETTINGS)
def test_loss_matches_oracle(seed):
    p, x, y = _inputs(seed)
    lo = R.softmax_xent_ref(M.logits_oracle(SPEC, p, x), y.astype(jnp.int32))
    np.testing.assert_allclose(float(M.loss(SPEC, p, x, y)[0]), float(lo),
                               rtol=1e-5, atol=1e-6)


@given(seed=st.integers(0, 2**31 - 1))
@settings(**_SETTINGS)
def test_grad_matches_oracle_grad(seed):
    p, x, y = _inputs(seed)
    g, gl = M.grad(SPEC, p, x, y)
    go = jax.grad(lambda pp: R.softmax_xent_ref(
        M.logits_oracle(SPEC, pp, x), y.astype(jnp.int32)))(p)
    np.testing.assert_allclose(np.asarray(g), np.asarray(go),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(float(gl), float(M.loss(SPEC, p, x, y)[0]),
                               rtol=1e-6)


def test_grad_matches_numerical():
    """Central finite differences on a handful of coordinates."""
    p, x, y = _inputs(11, scale=0.2)
    g, _ = M.grad(SPEC, p, x, y)
    eps = 1e-3
    for idx in [0, 7, SPEC.dim // 2, SPEC.dim - 1]:
        e = np.zeros(SPEC.dim, np.float32)
        e[idx] = eps
        lp = float(M.loss(SPEC, p + jnp.asarray(e), x, y)[0])
        lm = float(M.loss(SPEC, p - jnp.asarray(e), x, y)[0])
        num = (lp - lm) / (2 * eps)
        assert abs(num - float(g[idx])) < 5e-3, (idx, num, float(g[idx]))


# ---------------------------------------------------------------------------
# loss_pair / ZO estimator properties
# ---------------------------------------------------------------------------


def test_loss_pair_base_equals_loss():
    p, x, y = _inputs(2)
    v = _unit_dir(3, SPEC.dim)
    lp, lb = M.loss_pair(SPEC, p, v, jnp.float32(1e-3), x, y)
    np.testing.assert_allclose(float(lb), float(M.loss(SPEC, p, x, y)[0]),
                               rtol=1e-6)
    assert float(lp) != float(lb)  # generic direction moves the loss


def test_loss_pair_plus_equals_shifted_loss():
    p, x, y = _inputs(4)
    v = _unit_dir(5, SPEC.dim)
    mu = jnp.float32(1e-2)
    lp, _ = M.loss_pair(SPEC, p, v, mu, x, y)
    direct = float(M.loss(SPEC, p + mu * v, x, y)[0])
    np.testing.assert_allclose(float(lp), direct, rtol=1e-5, atol=1e-6)


def test_zo_scalar_approximates_directional_derivative():
    """(F(x+mu v)-F(x))/mu -> <grad, v> as mu -> 0 (the estimator core)."""
    p, x, y = _inputs(6, scale=0.2)
    v = _unit_dir(7, SPEC.dim)
    g, _ = M.grad(SPEC, p, x, y)
    dd = float(jnp.dot(g, v))
    mu = 1e-4
    lp, lb = M.loss_pair(SPEC, p, v, jnp.float32(mu), x, y)
    fd = (float(lp) - float(lb)) / mu
    assert abs(fd - dd) < 5e-2 * max(1.0, abs(dd)), (fd, dd)


# ---------------------------------------------------------------------------
# accuracy / predict
# ---------------------------------------------------------------------------


def test_accuracy_bounds_and_value():
    p, x, y = _inputs(8)
    acc = float(M.accuracy(SPEC, p, x, y)[0])
    assert 0.0 <= acc <= BATCH
    pred = np.argmax(np.asarray(M.predict(SPEC, p, x)[0]), axis=-1)
    assert acc == float(np.sum(pred == np.asarray(y).astype(np.int64)))


def test_accuracy_perfect_when_labels_are_predictions():
    p, x, _ = _inputs(9)
    pred = np.argmax(np.asarray(M.predict(SPEC, p, x)[0]), axis=-1)
    acc = float(M.accuracy(SPEC, p, x, jnp.asarray(pred.astype(np.float32)))[0])
    assert acc == BATCH


# ---------------------------------------------------------------------------
# CW attack objective (Appendix A)
# ---------------------------------------------------------------------------

CLF = M.MLPSpec(features=36, hidden1=12, hidden2=8, classes=4)
NIMG = 5


def _attack_inputs(seed):
    rng = np.random.default_rng(seed)
    cp = jnp.asarray(rng.normal(size=CLF.dim, scale=0.3).astype(np.float32))
    img = jnp.asarray((0.45 * np.tanh(rng.normal(size=(NIMG, 36)))).astype(np.float32))
    y = jnp.asarray((rng.integers(0, 4, size=NIMG)).astype(np.float32))
    return cp, img, y


def test_attack_zero_perturbation_zero_distortion():
    cp, img, _ = _attack_inputs(0)
    xp = jnp.zeros((36,), jnp.float32)
    _, dist = M.attack_eval(CLF, xp, cp, img)
    np.testing.assert_allclose(np.asarray(dist), 0.0, atol=1e-5)


def test_attack_loss_zero_c_is_pure_distortion():
    cp, img, y = _attack_inputs(1)
    xp = jnp.asarray(np.full(36, 0.05, np.float32))
    lo = float(M.attack_loss(CLF, xp, cp, img, y, jnp.float32(0.0))[0])
    z = 0.5 * jnp.tanh(jnp.arctanh(2.0 * img) + xp[None, :])
    expect = float(jnp.mean(jnp.sum((z - img) ** 2, axis=-1)))
    np.testing.assert_allclose(lo, expect, rtol=1e-5)


def test_attack_loss_monotone_in_c():
    cp, img, y = _attack_inputs(2)
    xp = jnp.asarray(np.full(36, 0.02, np.float32))
    l1 = float(M.attack_loss(CLF, xp, cp, img, y, jnp.float32(0.1))[0])
    l2 = float(M.attack_loss(CLF, xp, cp, img, y, jnp.float32(10.0))[0])
    assert l2 >= l1  # margin term is non-negative


def test_attack_grad_matches_numerical():
    cp, img, y = _attack_inputs(3)
    xp = jnp.asarray(np.full(36, 0.01, np.float32))
    c = jnp.float32(0.5)
    g, gl = M.attack_grad(CLF, xp, cp, img, y, c)
    eps = 1e-3
    for idx in [0, 5, 17, 35]:
        e = np.zeros(36, np.float32)
        e[idx] = eps
        lp = float(M.attack_loss(CLF, xp + jnp.asarray(e), cp, img, y, c)[0])
        lm = float(M.attack_loss(CLF, xp - jnp.asarray(e), cp, img, y, c)[0])
        num = (lp - lm) / (2 * eps)
        assert abs(num - float(g[idx])) < 5e-3


def test_attack_pair_base_matches_loss():
    cp, img, y = _attack_inputs(4)
    xp = jnp.asarray(np.full(36, 0.01, np.float32))
    v = _unit_dir(5, 36)
    lp, lb = M.attack_pair(CLF, xp, v, jnp.float32(1e-3), cp, img, y,
                           jnp.float32(0.5))
    np.testing.assert_allclose(
        float(lb),
        float(M.attack_loss(CLF, xp, cp, img, y, jnp.float32(0.5))[0]),
        rtol=1e-6)


def test_attack_images_stay_in_valid_box():
    cp, img, _ = _attack_inputs(6)
    xp = jnp.asarray(np.full(36, 3.0, np.float32))  # huge perturbation
    z = 0.5 * jnp.tanh(jnp.arctanh(2.0 * img) + xp[None, :])
    assert float(jnp.max(jnp.abs(z))) <= 0.5 + 1e-6
