"""AOT pipeline tests: HLO-text lowering round-trips, manifest integrity,
and golden-value reproducibility (the values rust/tests/golden.rs checks).
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model as M

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_profiles_well_formed():
    for name, tup in aot.PROFILES.items():
        assert len(tup) == 5
        s = aot.spec_of(name)
        assert s.dim > 0 and s.classes >= 2


def test_table4_profiles_match_paper():
    """Feature/class counts of the Fig. 2 datasets must match Table 4."""
    expected = {  # dataset -> (features, classes)
        "sensorless": (48, 11),
        "acoustic": (50, 3),
        "covtype": (54, 7),
        "seismic": (50, 3),
    }
    for name, (f, c) in expected.items():
        s = aot.spec_of(name)
        assert (s.features, s.classes) == (f, c), name


def test_lowering_produces_parseable_hlo_text():
    spec = aot.spec_of("quickstart")
    fn, specs = aot.mlp_entrypoints(spec, 8)["loss"]
    text = aot.lower(fn, *specs)
    assert "HloModule" in text
    assert "ROOT" in text
    # tuple return: final root should be a tuple
    assert "tuple(" in text or "tuple " in text


def test_golden_inputs_are_deterministic():
    a = aot.golden_params(100)
    b = aot.golden_params(100)
    np.testing.assert_array_equal(a, b)
    x1, y1 = aot.golden_batch(8, 10, 3)
    x2, y2 = aot.golden_batch(8, 10, 3)
    np.testing.assert_array_equal(x1, x2)
    np.testing.assert_array_equal(y1, y2)


def test_golden_direction_is_unit():
    v = aot.golden_direction(900)
    assert abs(float(np.linalg.norm(v.astype(np.float64))) - 1.0) < 1e-5


def test_golden_images_in_valid_range():
    img = aot.golden_images(10, 900)
    assert np.max(np.abs(img)) < 0.5  # atanh(2a) must be finite


def test_golden_values_reproduce():
    g1 = aot.golden_for_profile("quickstart")
    g2 = aot.golden_for_profile("quickstart")
    assert g1 == g2
    assert np.isfinite(g1["loss"]) and g1["grad_norm"] > 0


@pytest.mark.pjrt
@pytest.mark.skipif(not os.path.exists(os.path.join(ART_DIR, "manifest.json")),
                    reason="artifacts not built (run `make artifacts`)")
class TestBuiltArtifacts:
    @pytest.fixture(scope="class")
    def manifest(self):
        with open(os.path.join(ART_DIR, "manifest.json")) as f:
            return json.load(f)

    def test_manifest_covers_all_profiles(self, manifest):
        assert set(manifest["profiles"]) == set(aot.PROFILES)
        assert manifest["attack"] is not None

    def test_all_artifact_files_exist_and_are_hlo(self, manifest):
        names = []
        for prof in manifest["profiles"].values():
            names += list(prof["artifacts"].values())
        names += list(manifest["attack"]["artifacts"].values())
        assert len(names) == len(set(names))
        for n in names:
            path = os.path.join(ART_DIR, n)
            assert os.path.exists(path), n
            with open(path) as f:
                head = f.read(200)
            assert "HloModule" in head, n

    def test_manifest_dims_match_specs(self, manifest):
        for name, prof in manifest["profiles"].items():
            assert prof["dim"] == aot.spec_of(name).dim

    def test_golden_loss_matches_recompute(self, manifest):
        g = manifest["profiles"]["quickstart"]["golden"]
        fresh = aot.golden_for_profile("quickstart")
        assert abs(g["loss"] - fresh["loss"]) < 1e-6
        assert abs(g["pair_base"] - fresh["pair_base"]) < 1e-6

    def test_attack_manifest_dims(self, manifest):
        a = manifest["attack"]
        assert a["image_dim"] == aot.IMAGE_DIM == 900  # 30x30, paper d=900
        assert a["batch"] == aot.ATTACK_BATCH == 5     # paper B=5
        assert a["eval_batch"] == aot.ATTACK_EVAL_BATCH == 10  # paper n=10
