"""L1 Pallas kernel for the zeroth-order parameter perturbation.

``perturb(params, direction, mu) = params + mu * direction`` — the axpy that
produces the ZO probe point ``x^t + mu * v`` of Algorithm 1, eq. (4). It is
fused into the ``loss_pair`` artifact so a ZO iteration costs exactly one
executable dispatch from the rust hot path (two function evaluations, one
launch).

The grid is 1-D over contiguous f32 blocks; the scalar ``mu`` rides along as
a (1,)-shaped operand mapped to every instance. Like all L1 kernels this is
``interpret=True`` (see kernels/dense.py for why) and is differentiable via
an explicit custom_vjp (d/dp = g, d/dv = mu*g, d/dmu = <g, v>).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

PERTURB_BLOCK = 4096


def _perturb_kernel(p_ref, v_ref, mu_ref, o_ref):
    o_ref[...] = p_ref[...] + mu_ref[0] * v_ref[...]


def _perturb_pallas(params: jax.Array, direction: jax.Array,
                    mu: jax.Array) -> jax.Array:
    d = params.shape[0]
    blk = min(PERTURB_BLOCK, d)
    pad = (-d) % blk
    p = jnp.pad(params, (0, pad)) if pad else params
    v = jnp.pad(direction, (0, pad)) if pad else direction
    mu1 = jnp.reshape(mu, (1,)).astype(jnp.float32)
    out = pl.pallas_call(
        _perturb_kernel,
        grid=((d + pad) // blk,),
        in_specs=[
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((blk,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((d + pad,), jnp.float32),
        interpret=True,
    )(p, v, mu1)
    return out[:d] if pad else out


@jax.custom_vjp
def perturb(params: jax.Array, direction: jax.Array, mu: jax.Array) -> jax.Array:
    """params + mu * direction, as a blocked Pallas axpy."""
    return _perturb_pallas(params, direction, mu)


def _perturb_fwd(params, direction, mu):
    return perturb(params, direction, mu), (direction, mu)


def _perturb_bwd(res, g):
    direction, mu = res
    return g, mu * g, jnp.sum(g * direction)


perturb.defvjp(_perturb_fwd, _perturb_bwd)
