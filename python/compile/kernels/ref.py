"""Pure-jnp oracles for every L1 Pallas kernel.

These are the CORE correctness signal: ``python/tests/test_kernel.py``
asserts ``assert_allclose(pallas(...), ref(...))`` under hypothesis-driven
shape/value sweeps, and the L2 model (``compile/model.py``) is additionally
cross-checked against a full-oracle model built only from these functions.
Nothing here may import pallas.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def dense_relu_ref(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    return jnp.maximum(x @ w + b, 0.0)


def dense_linear_ref(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    return x @ w + b


def perturb_ref(params: jax.Array, direction: jax.Array,
                mu: jax.Array) -> jax.Array:
    return params + jnp.reshape(mu, ()) * direction


def softmax_xent_ref(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean softmax cross-entropy; labels are int32 class ids."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    picked = jnp.take_along_axis(logp, labels[:, None].astype(jnp.int32),
                                 axis=-1)[:, 0]
    return -jnp.mean(picked)
