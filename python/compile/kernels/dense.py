"""L1 Pallas kernels: blocked fused dense layers (matmul + bias + activation).

These are the compute hot-spot of the HO-SGD model stack (the 2-hidden-layer
MLP of the paper's Section 5.2 experiments, and the frozen classifier inside
the Section 5.1 CW attack loss).

TPU mapping: the grid is 2-D over
(batch-blocks, out-feature-blocks); each kernel instance holds one
``(bB, F)`` activation block and one ``(F, bH)`` weight block in VMEM and
performs a full-K contraction feeding MXU-shaped tiles. ``interpret=True``
is mandatory here — the CPU PJRT plugin cannot execute Mosaic custom-calls —
so the BlockSpec expresses the HBM<->VMEM schedule structurally and the
real-TPU efficiency is estimated from the block footprint (see
``vmem_footprint_bytes`` and EXPERIMENTS.md §Perf), not from wallclock.

``jax.grad`` does not differentiate through ``pallas_call``; every public
entry point carries a ``custom_vjp`` whose backward pass is expressed with
plain jnp matmuls (which XLA fuses on its own). The forward values produced
by the Pallas path are validated against the pure-jnp oracle in
``kernels/ref.py`` by ``python/tests/test_kernel.py`` (hypothesis sweeps).
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default block shape: 128 matches the MXU systolic-array edge; a
# (128 x F) f32 activation block plus a (F x 128) weight block stays well
# inside a 16 MiB VMEM budget for every model profile we ship (F <= 1024).
DEFAULT_BLOCK_B = 128
DEFAULT_BLOCK_H = 128
VMEM_BUDGET_BYTES = 16 * 1024 * 1024


def _ceil_to(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


def vmem_footprint_bytes(batch: int, features: int, out: int,
                         block_b: int = DEFAULT_BLOCK_B,
                         block_h: int = DEFAULT_BLOCK_H) -> int:
    """Estimated per-instance VMEM residency of one dense kernel invocation.

    x-block (bB, F) + w-block (F, bH) + bias (bH,) + out-block (bB, bH),
    all f32. Used by the §Perf analysis and asserted < VMEM_BUDGET_BYTES in
    the kernel tests.
    """
    bb = min(block_b, _ceil_to(batch, 8))
    bh = min(block_h, _ceil_to(out, 8))
    f = features
    return 4 * (bb * f + f * bh + bh + bb * bh)


def _dense_kernel(x_ref, w_ref, b_ref, o_ref, *, relu: bool):
    """One grid instance: full-K contraction of an x-block with a w-block."""
    acc = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
    acc = acc + b_ref[...][None, :]
    if relu:
        acc = jnp.maximum(acc, 0.0)
    o_ref[...] = acc


def _dense_pallas(x: jax.Array, w: jax.Array, b: jax.Array, relu: bool,
                  block_b: int, block_h: int) -> jax.Array:
    """Zero-pad to block multiples, run the blocked kernel, slice back.

    Zero padding is exact for matmul+bias (padded rows/cols are discarded by
    the final slice), so numerics match the unpadded oracle bit-for-bit up
    to reduction order.
    """
    batch, features = x.shape
    fout = w.shape[1]
    bb = min(block_b, _ceil_to(batch, 8))
    bh = min(block_h, _ceil_to(fout, 8))
    pb = _ceil_to(batch, bb)
    ph = _ceil_to(fout, bh)

    xp = jnp.pad(x, ((0, pb - batch), (0, 0))) if pb != batch else x
    wp = jnp.pad(w, ((0, 0), (0, ph - fout))) if ph != fout else w
    bp = jnp.pad(b, (0, ph - fout)) if ph != fout else b

    out = pl.pallas_call(
        functools.partial(_dense_kernel, relu=relu),
        grid=(pb // bb, ph // bh),
        in_specs=[
            pl.BlockSpec((bb, features), lambda i, j: (i, 0)),
            pl.BlockSpec((features, bh), lambda i, j: (0, j)),
            pl.BlockSpec((bh,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bb, bh), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((pb, ph), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(xp, wp, bp)
    if pb != batch or ph != fout:
        out = out[:batch, :fout]
    return out


# ---------------------------------------------------------------------------
# custom_vjp wrappers. Forward = Pallas kernel; backward = jnp matmuls.
# ---------------------------------------------------------------------------


@jax.custom_vjp
def dense_relu(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """relu(x @ w + b) via the blocked Pallas kernel."""
    return _dense_pallas(x, w, b, relu=True,
                         block_b=DEFAULT_BLOCK_B, block_h=DEFAULT_BLOCK_H)


def _dense_relu_fwd(x, w, b):
    out = dense_relu(x, w, b)
    return out, (x, w, out)


def _dense_relu_bwd(res, g):
    x, w, out = res
    dz = g * (out > 0.0).astype(g.dtype)
    dx = dz @ w.T
    dw = x.T @ dz
    db = jnp.sum(dz, axis=0)
    return dx, dw, db


dense_relu.defvjp(_dense_relu_fwd, _dense_relu_bwd)


@jax.custom_vjp
def dense_linear(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """x @ w + b via the blocked Pallas kernel (no activation)."""
    return _dense_pallas(x, w, b, relu=False,
                         block_b=DEFAULT_BLOCK_B, block_h=DEFAULT_BLOCK_H)


def _dense_linear_fwd(x, w, b):
    return dense_linear(x, w, b), (x, w)


def _dense_linear_bwd(res, g):
    x, w = res
    return g @ w.T, x.T @ g, jnp.sum(g, axis=0)


dense_linear.defvjp(_dense_linear_fwd, _dense_linear_bwd)


def dense_shapes_ok(batch: int, features: int, out: int) -> Tuple[bool, int]:
    """(fits_in_vmem, footprint) — used by tests and the §Perf report."""
    fp = vmem_footprint_bytes(batch, features, out)
    return fp <= VMEM_BUDGET_BYTES, fp
