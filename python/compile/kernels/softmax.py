"""L1 Pallas kernel: fused softmax cross-entropy over a logits block.

Completes the Pallas coverage of the training hot path: with
``kernels.dense`` producing the logits and this kernel reducing them to the
scalar loss, the entire L2 ``loss`` graph bottoms out in Pallas kernels.

Each grid instance holds one ``(bB, C)`` logits block plus the matching
label block in VMEM and emits per-row cross-entropy contributions:
``xent_row = logsumexp(row) - row[label]`` (numerically stabilized by the
row max). The mean over the batch happens in the wrapper. ``custom_vjp``
backward is the classic ``(softmax - onehot)/B`` expressed in jnp.

interpret=True as for all L1 kernels (see kernels/dense.py).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_B = 128


def _ceil_to(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


def _xent_kernel(lg_ref, y_ref, o_ref):
    lg = lg_ref[...]  # (bB, C)
    y = y_ref[...].astype(jnp.int32)  # (bB,)
    m = jnp.max(lg, axis=-1, keepdims=True)
    lse = jnp.log(jnp.sum(jnp.exp(lg - m), axis=-1)) + m[:, 0]
    classes = lg.shape[-1]
    onehot = (
        jax.lax.broadcasted_iota(jnp.int32, lg.shape, 1) == y[:, None]
    ).astype(lg.dtype)
    picked = jnp.sum(lg * onehot, axis=-1)
    o_ref[...] = lse - picked


def _xent_rows_pallas(logits: jax.Array, labels: jax.Array) -> jax.Array:
    batch, classes = logits.shape
    bb = min(BLOCK_B, _ceil_to(batch, 8))
    pb = _ceil_to(batch, bb)
    lg = jnp.pad(logits, ((0, pb - batch), (0, 0))) if pb != batch else logits
    # padded labels point at class 0; their rows are sliced away below
    y = jnp.pad(labels, (0, pb - batch)) if pb != batch else labels
    rows = pl.pallas_call(
        _xent_kernel,
        grid=(pb // bb,),
        in_specs=[
            pl.BlockSpec((bb, classes), lambda i: (i, 0)),
            pl.BlockSpec((bb,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((bb,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((pb,), jnp.float32),
        interpret=True,
    )(lg, y)
    return rows[:batch] if pb != batch else rows


@jax.custom_vjp
def softmax_xent(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean softmax cross-entropy via the fused Pallas kernel.

    ``labels`` are f32 class ids (the FFI label encoding).
    """
    return jnp.mean(_xent_rows_pallas(logits, labels))


def _softmax_xent_fwd(logits, labels):
    return softmax_xent(logits, labels), (logits, labels)


def _softmax_xent_bwd(res, g):
    logits, labels = res
    batch = logits.shape[0]
    p = jax.nn.softmax(logits, axis=-1)
    onehot = jax.nn.one_hot(labels.astype(jnp.int32), logits.shape[-1],
                            dtype=logits.dtype)
    return (g * (p - onehot) / batch, None)


softmax_xent.defvjp(_softmax_xent_fwd, _softmax_xent_bwd)
