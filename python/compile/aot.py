"""AOT pipeline: lower every (profile, entrypoint) to HLO TEXT artifacts.

Run once at build time (``make artifacts``); the rust coordinator is fully
self-contained afterwards. Interchange is HLO *text*, not a serialized
``HloModuleProto``: jax >= 0.5 emits protos with 64-bit instruction ids that
xla_extension 0.5.1 (the version behind the published ``xla`` 0.1.6 crate)
rejects; the text parser reassigns ids and round-trips cleanly.

Every entry point returns a tuple and is lowered with ``return_tuple=True``;
the rust runtime unwraps with ``to_tuple*``.

Besides the ``.hlo.txt`` files this writes ``manifest.json``:
  - per-profile dims/shapes (the rust runtime validates literals against it)
  - golden values on deterministic inputs (see ``golden_*`` below), which
    ``rust/tests/golden.rs`` recomputes through the PJRT path — the
    cross-language end-to-end numerics check.
"""

from __future__ import annotations

import argparse
import json
import math
import os
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M

F32 = jnp.float32


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def lower(fn, *specs) -> str:
    return to_hlo_text(jax.jit(fn).lower(*specs))


# ---------------------------------------------------------------------------
# Model profiles (Table 4 of the paper, scaled down to the CPU testbed;
# mirrored by rust/src/backend/native.rs::PROFILES)
# ---------------------------------------------------------------------------

# name -> (features, hidden1, hidden2, classes, train_batch)
PROFILES = {
    # tiny model for the quickstart example and fast tests
    "quickstart": (10, 16, 16, 3, 8),
    # the four Fig. 2 dataset profiles: feature/class counts match Table 4,
    # hidden sizes scaled from the paper's 1.3K/1.3K to fit the CPU testbed
    "sensorless": (48, 128, 128, 11, 64),
    "acoustic": (50, 128, 128, 3, 64),
    "covtype": (54, 128, 128, 7, 64),
    "seismic": (50, 128, 128, 3, 64),
    # the end-to-end driver model (largest profile we AOT-compile)
    "e2e": (64, 256, 256, 10, 64),
    # the frozen classifier attacked in Section 5.1 (d_img = 900 = 30x30)
    "attack_clf": (900, 64, 32, 10, 64),
}

ATTACK_BATCH = 5       # per-worker image batch for the attack objective
ATTACK_EVAL_BATCH = 10  # n = 10 images are evaluated/reported (Table 3)
IMAGE_DIM = 900


def spec_of(name: str) -> M.MLPSpec:
    f, h1, h2, c, _ = PROFILES[name]
    return M.MLPSpec(features=f, hidden1=h1, hidden2=h2, classes=c)


# ---------------------------------------------------------------------------
# Deterministic golden inputs — replicated bit-compatibly in rust
# (rust/src/runtime/golden.rs uses the same closed-form f64 formulas).
# ---------------------------------------------------------------------------


def golden_params(d: int) -> np.ndarray:
    i = np.arange(d, dtype=np.float64)
    return (0.1 * np.sin(0.01 * i + 0.5)).astype(np.float32)


def golden_batch(batch: int, features: int, classes: int):
    b = np.arange(batch, dtype=np.float64)[:, None]
    f = np.arange(features, dtype=np.float64)[None, :]
    x = np.sin(0.1 * b + 0.01 * f).astype(np.float32)
    y = (np.arange(batch) % classes).astype(np.float64).astype(np.float32)
    return x, y


def golden_direction(d: int) -> np.ndarray:
    i = np.arange(d, dtype=np.float64)
    v = np.cos(0.01 * i + 0.1)
    v = v / np.sqrt(np.sum(v * v))
    return v.astype(np.float32)


def golden_images(batch: int, dim: int) -> np.ndarray:
    b = np.arange(batch, dtype=np.float64)[:, None]
    f = np.arange(dim, dtype=np.float64)[None, :]
    return (0.45 * np.sin(0.07 * b + 0.013 * f)).astype(np.float32)


GOLDEN_MU = 1e-3
GOLDEN_C = 0.5


# ---------------------------------------------------------------------------
# Lowering
# ---------------------------------------------------------------------------


def mlp_entrypoints(spec: M.MLPSpec, batch: int):
    """name -> (fn, arg ShapeDtypeStructs) for one MLP profile."""
    d = spec.dim
    p = jax.ShapeDtypeStruct((d,), F32)
    v = jax.ShapeDtypeStruct((d,), F32)
    mu = jax.ShapeDtypeStruct((), F32)
    x = jax.ShapeDtypeStruct((batch, spec.features), F32)
    y = jax.ShapeDtypeStruct((batch,), F32)
    return {
        "loss": (partial(M.loss, spec), (p, x, y)),
        "grad": (partial(M.grad, spec), (p, x, y)),
        "loss_pair": (partial(M.loss_pair, spec), (p, v, mu, x, y)),
        "accuracy": (partial(M.accuracy, spec), (p, x, y)),
        "predict": (partial(M.predict, spec), (p, x)),
    }


def attack_entrypoints(clf: M.MLPSpec):
    dc = clf.dim
    xp = jax.ShapeDtypeStruct((IMAGE_DIM,), F32)
    v = jax.ShapeDtypeStruct((IMAGE_DIM,), F32)
    mu = jax.ShapeDtypeStruct((), F32)
    cp = jax.ShapeDtypeStruct((dc,), F32)
    img = jax.ShapeDtypeStruct((ATTACK_BATCH, IMAGE_DIM), F32)
    y = jax.ShapeDtypeStruct((ATTACK_BATCH,), F32)
    c = jax.ShapeDtypeStruct((), F32)
    img_e = jax.ShapeDtypeStruct((ATTACK_EVAL_BATCH, IMAGE_DIM), F32)
    return {
        "attack_loss": (partial(M.attack_loss, clf), (xp, cp, img, y, c)),
        "attack_grad": (partial(M.attack_grad, clf), (xp, cp, img, y, c)),
        "attack_pair": (partial(M.attack_pair, clf), (xp, v, mu, cp, img, y, c)),
        "attack_eval": (partial(M.attack_eval, clf), (xp, cp, img_e)),
    }


def golden_for_profile(name: str) -> dict:
    spec, batch = spec_of(name), PROFILES[name][4]
    d = spec.dim
    p = jnp.asarray(golden_params(d))
    xg, yg = golden_batch(batch, spec.features, spec.classes)
    x, y = jnp.asarray(xg), jnp.asarray(yg)
    v = jnp.asarray(golden_direction(d))
    mu = jnp.float32(GOLDEN_MU)
    lo = float(M.loss(spec, p, x, y)[0])
    g, gl = M.grad(spec, p, x, y)
    lp, lb = M.loss_pair(spec, p, v, mu, x, y)
    acc = float(M.accuracy(spec, p, x, y)[0])
    return {
        "mu": GOLDEN_MU,
        "loss": lo,
        "grad_loss": float(gl),
        "grad_norm": float(jnp.linalg.norm(g)),
        "grad_head": [float(t) for t in np.asarray(g[:4])],
        "pair_plus": float(lp),
        "pair_base": float(lb),
        "accuracy": acc,
    }


def golden_for_attack(clf: M.MLPSpec) -> dict:
    xp = jnp.zeros((IMAGE_DIM,), F32) + 0.01
    cp = jnp.asarray(golden_params(clf.dim))
    img = jnp.asarray(golden_images(ATTACK_BATCH, IMAGE_DIM))
    y = jnp.asarray((np.arange(ATTACK_BATCH) % clf.classes).astype(np.float32))
    c = jnp.float32(GOLDEN_C)
    v = jnp.asarray(golden_direction(IMAGE_DIM))
    mu = jnp.float32(GOLDEN_MU)
    lo = float(M.attack_loss(clf, xp, cp, img, y, c)[0])
    g, gl = M.attack_grad(clf, xp, cp, img, y, c)
    lp, lb = M.attack_pair(clf, xp, v, mu, cp, img, y, c)
    img_e = jnp.asarray(golden_images(ATTACK_EVAL_BATCH, IMAGE_DIM))
    lg, dist = M.attack_eval(clf, xp, cp, img_e)
    return {
        "mu": GOLDEN_MU,
        "c": GOLDEN_C,
        "loss": lo,
        "grad_loss": float(gl),
        "grad_norm": float(jnp.linalg.norm(g)),
        "grad_head": [float(t) for t in np.asarray(g[:4])],
        "pair_plus": float(lp),
        "pair_base": float(lb),
        "eval_logit00": float(lg[0, 0]),
        "eval_dist0": float(dist[0]),
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts",
                    help="artifact output directory")
    ap.add_argument("--profiles", default="",
                    help="comma-separated subset of profiles (default: all)")
    ap.add_argument("--skip-golden", action="store_true",
                    help="skip golden-value evaluation (faster CI iteration)")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    wanted = [s for s in args.profiles.split(",") if s] or list(PROFILES)

    manifest = {"version": 1, "profiles": {}, "attack": None}

    for name in wanted:
        spec, batch = spec_of(name), PROFILES[name][4]
        arts = {}
        for ep, (fn, specs) in mlp_entrypoints(spec, batch).items():
            fname = f"{name}_{ep}.hlo.txt"
            text = lower(fn, *specs)
            with open(os.path.join(args.out_dir, fname), "w") as f:
                f.write(text)
            arts[ep] = fname
            print(f"lowered {fname} ({len(text)} chars)")
        manifest["profiles"][name] = {
            "features": spec.features,
            "hidden1": spec.hidden1,
            "hidden2": spec.hidden2,
            "classes": spec.classes,
            "dim": spec.dim,
            "batch": batch,
            "artifacts": arts,
            "golden": None if args.skip_golden else golden_for_profile(name),
        }

    if "attack_clf" in wanted:
        clf = spec_of("attack_clf")
        arts = {}
        for ep, (fn, specs) in attack_entrypoints(clf).items():
            fname = f"attack_{ep}.hlo.txt"
            text = lower(fn, *specs)
            with open(os.path.join(args.out_dir, fname), "w") as f:
                f.write(text)
            arts[ep] = fname
            print(f"lowered {fname} ({len(text)} chars)")
        manifest["attack"] = {
            "clf_profile": "attack_clf",
            "image_dim": IMAGE_DIM,
            "batch": ATTACK_BATCH,
            "eval_batch": ATTACK_EVAL_BATCH,
            "artifacts": arts,
            "golden": None if args.skip_golden else golden_for_attack(clf),
        }

    mpath = os.path.join(args.out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {mpath}")


if __name__ == "__main__":
    main()
