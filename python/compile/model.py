"""L2: the paper's compute graphs in JAX, built on the L1 Pallas kernels.

Two families of graphs, matching the paper's two experiment sections:

1. **Multi-class MLP** (Section 5.2 / Fig. 2): the "high-dimensional fully
   connected two-layer neural network" — features -> hidden1 -> hidden2 ->
   classes with relu — operating on a FLAT f32[d] parameter vector so the
   rust coordinator treats the model opaquely as ``x in R^d`` exactly like
   Algorithm 1 does.

2. **CW universal-perturbation attack loss** (Section 5.1 / Appendix A):
   the Carlini–Wagner objective over a frozen classifier, whose decision
   variable is the d=900-dim universal perturbation.

Every public entry point is a pure function ``(flat tensors) -> tuple`` and
is lowered ONCE by ``aot.py`` to HLO text; python never runs at training
time. Labels cross the FFI as f32 and are cast to int32 inside the graph to
keep the rust literal surface f32-only.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import jax
import jax.numpy as jnp

from .kernels.dense import dense_linear, dense_relu
from .kernels.ref import dense_linear_ref, dense_relu_ref, softmax_xent_ref
from .kernels.softmax import softmax_xent
from .kernels.zo import perturb


# ---------------------------------------------------------------------------
# Model spec & flat-parameter layout
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MLPSpec:
    """2-hidden-layer MLP; the paper's Section 5.2 base model (scaled)."""

    features: int
    hidden1: int
    hidden2: int
    classes: int

    @property
    def dim(self) -> int:
        """d — total flat parameter count (the paper's model dimension)."""
        f, h1, h2, c = self.features, self.hidden1, self.hidden2, self.classes
        return f * h1 + h1 + h1 * h2 + h2 + h2 * c + c

    def shapes(self) -> Tuple[Tuple[int, ...], ...]:
        f, h1, h2, c = self.features, self.hidden1, self.hidden2, self.classes
        return ((f, h1), (h1,), (h1, h2), (h2,), (h2, c), (c,))


def unflatten(spec: MLPSpec, params: jax.Array):
    """Split the flat f32[d] vector into (W1,b1,W2,b2,W3,b3)."""
    out, off = [], 0
    for shp in spec.shapes():
        n = 1
        for s in shp:
            n *= s
        out.append(params[off:off + n].reshape(shp))
        off += n
    return tuple(out)


def logits(spec: MLPSpec, params: jax.Array, x: jax.Array) -> jax.Array:
    """Forward pass through the Pallas dense kernels."""
    w1, b1, w2, b2, w3, b3 = unflatten(spec, params)
    h = dense_relu(x, w1, b1)
    h = dense_relu(h, w2, b2)
    return dense_linear(h, w3, b3)


def logits_oracle(spec: MLPSpec, params: jax.Array, x: jax.Array) -> jax.Array:
    """Same forward built only from ref.py — the kernel-free oracle."""
    w1, b1, w2, b2, w3, b3 = unflatten(spec, params)
    h = dense_relu_ref(x, w1, b1)
    h = dense_relu_ref(h, w2, b2)
    return dense_linear_ref(h, w3, b3)


# ---------------------------------------------------------------------------
# Training-objective entry points (Section 5.2)
# ---------------------------------------------------------------------------


def loss(spec: MLPSpec, params: jax.Array, x: jax.Array,
         y: jax.Array) -> Tuple[jax.Array]:
    """Mean softmax cross-entropy (fused Pallas kernel). y is f32[B] ids."""
    lg = logits(spec, params, x)
    return (softmax_xent(lg, y),)


def grad(spec: MLPSpec, params: jax.Array, x: jax.Array,
         y: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """(dL/dparams, L) — the first-order SFO of Algorithm 1 eq. (3)."""
    val, g = jax.value_and_grad(lambda p: loss(spec, p, x, y)[0])(params)
    return (g, val)


def loss_pair(spec: MLPSpec, params: jax.Array, v: jax.Array, mu: jax.Array,
              x: jax.Array, y: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """(F(x+mu*v, batch), F(x, batch)) — both ZO probe evals, one dispatch.

    This is the whole per-iteration compute of a ZO step (Algorithm 1
    eq. (4)); fusing both function evaluations into one executable halves
    the rust-side dispatch count on the hot path.
    """
    p_plus = perturb(params, v, mu)
    lp = loss(spec, p_plus, x, y)[0]
    lb = loss(spec, params, x, y)[0]
    return (lp, lb)


def accuracy(spec: MLPSpec, params: jax.Array, x: jax.Array,
             y: jax.Array) -> Tuple[jax.Array]:
    """Number of correct predictions in the batch, as f32."""
    pred = jnp.argmax(logits(spec, params, x), axis=-1)
    return (jnp.sum((pred == y.astype(jnp.int32)).astype(jnp.float32)),)


def predict(spec: MLPSpec, params: jax.Array,
            x: jax.Array) -> Tuple[jax.Array]:
    return (logits(spec, params, x),)


# ---------------------------------------------------------------------------
# CW universal-perturbation attack (Section 5.1 / Appendix A)
# ---------------------------------------------------------------------------


def _attack_images(xp: jax.Array, images: jax.Array) -> jax.Array:
    """z_k = 0.5*tanh(atanh(2 a_k) + xp): keep z in the valid image box."""
    return 0.5 * jnp.tanh(jnp.arctanh(2.0 * images) + xp[None, :])


def attack_loss(spec: MLPSpec, xp: jax.Array, clf_params: jax.Array,
                images: jax.Array, y: jax.Array,
                c: jax.Array) -> Tuple[jax.Array]:
    """Appendix A objective, averaged over the image batch.

    loss_k = c * max(0, f_{y_k}(z_k) - max_{j != y_k} f_j(z_k))
             + || z_k - a_k ||_2^2
    """
    z = _attack_images(xp, images)
    lg = logits(spec, clf_params, z)
    yi = y.astype(jnp.int32)
    b = images.shape[0]
    fy = jnp.take_along_axis(lg, yi[:, None], axis=-1)[:, 0]
    masked = lg - jax.nn.one_hot(yi, spec.classes, dtype=lg.dtype) * 1e9
    fmax = jnp.max(masked, axis=-1)
    margin = jnp.maximum(fy - fmax, 0.0)
    dist = jnp.sum((z - images) ** 2, axis=-1)
    return (jnp.mean(jnp.reshape(c, ()) * margin + dist),)


def attack_grad(spec: MLPSpec, xp: jax.Array, clf_params: jax.Array,
                images: jax.Array, y: jax.Array,
                c: jax.Array) -> Tuple[jax.Array, jax.Array]:
    val, g = jax.value_and_grad(
        lambda p: attack_loss(spec, p, clf_params, images, y, c)[0])(xp)
    return (g, val)


def attack_pair(spec: MLPSpec, xp: jax.Array, v: jax.Array, mu: jax.Array,
                clf_params: jax.Array, images: jax.Array, y: jax.Array,
                c: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """ZO two-point evaluation of the attack objective (one dispatch)."""
    xp_plus = perturb(xp, v, mu)
    lp = attack_loss(spec, xp_plus, clf_params, images, y, c)[0]
    lb = attack_loss(spec, xp, clf_params, images, y, c)[0]
    return (lp, lb)


def attack_eval(spec: MLPSpec, xp: jax.Array, clf_params: jax.Array,
                images: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """(logits over the adversarial images, per-image l2 distortion).

    The rust attack driver derives predicted labels, per-image success and
    Table 2's least-l2-distortion from these.
    """
    z = _attack_images(xp, images)
    lg = logits(spec, clf_params, z)
    dist = jnp.sqrt(jnp.sum((z - images) ** 2, axis=-1))
    return (lg, dist)
