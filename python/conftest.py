"""Make `compile.*` importable whether pytest runs from `python/` (the
Makefile path) or from the repository root (`pytest python/tests/`)."""

import os
import sys

sys.path.insert(0, os.path.dirname(__file__))


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "pjrt: exercises the AOT artifacts / PJRT execution path "
        "(deselect in CI with -m 'not pjrt')")
