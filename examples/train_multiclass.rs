//! Fig. 2 workload: distributed multi-class training on one of the Table-4
//! dataset profiles, comparing all five figure methods (HO-SGD, syncSGD,
//! RI-SGD, ZO-SGD, ZO-SVRG-Ave) from the same initial point.
//!
//! Run with:
//!   cargo run --release --example train_multiclass [dataset] [iters]
//! (defaults: sensorless 200; `HOSGD_THREADS=N` sizes the worker pool,
//! unset = available parallelism — results are identical at any count)

use std::path::Path;

use anyhow::Result;
use hosgd::backend::{self, Backend, ModelBackend};
use hosgd::config::{Method, StepSize, TrainConfig};
use hosgd::coordinator::{make_data, run_train_with};

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let dataset = args.get(1).map(String::as_str).unwrap_or("sensorless").to_string();
    let iters: u64 = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(200);

    let artifacts = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    let rt = backend::load_from_env("HOSGD_BACKEND", Path::new(artifacts))?;
    let model = rt.model(&dataset)?;
    println!(
        "== {dataset}: d = {}, m = 4 workers, B = {}, tau = 8, {iters} iters ==",
        model.dim(),
        model.batch()
    );

    let base = TrainConfig {
        dataset: dataset.clone(),
        iters,
        eval_every: (iters / 10).max(1),
        ..Default::default()
    };
    let data = make_data(&base)?;

    println!(
        "\n{:<14} {:>11} {:>10} {:>10} {:>14} {:>12}",
        "method", "final loss", "test acc", "compute_s", "sim comm (s)", "MB/worker"
    );
    for method in Method::FIGURE_SET {
        let alpha = match method {
            Method::ZoSgd => 0.005,
            Method::ZoSvrgAve => 0.002,
            Method::HoSgd => 0.005,
            _ => 0.1,
        };
        let cfg = TrainConfig { method, step: StepSize::Constant { alpha }, ..base.clone() };
        let out = run_train_with(model.as_ref(), &data, &cfg)?;
        let last = out.trace.rows.last().unwrap();
        println!(
            "{:<14} {:>11.4} {:>10} {:>10.2} {:>14.4} {:>12.3}",
            method.label(),
            last.train_loss,
            out.trace.final_acc().map_or("-".into(), |a| format!("{a:.3}")),
            last.compute_s,
            last.comm_s,
            last.bytes_per_worker as f64 / 1e6,
        );
    }
    println!(
        "\nExpected shape (EXPERIMENTS.md): HO-SGD ≥ ZO-SGD > ZO-SVRG per iteration\n\
         at tuned rates, while moving τ× fewer bytes than syncSGD (and ~d× fewer\n\
         on its ZO iterations) — the Table-1 communication/compute trade-off."
    );
    Ok(())
}
