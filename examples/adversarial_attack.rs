//! Section 5.1 workload: generate a universal adversarial perturbation
//! against a frozen classifier with distributed hybrid-order SGD
//! (Fig. 1 / Table 2 / Table 3).
//!
//! The example first trains the attack target with the library's own
//! syncSGD (the offline substitution for the paper's "well-trained DNN"),
//! then runs the CW attack with HO-SGD and prints the loss curve, per-image
//! outcomes and l2 distortions.
//!
//! Run with:
//!   cargo run --release --example adversarial_attack [method] [iters]
//! (`HOSGD_THREADS=N` sizes the pool the m = 5 attack workers fan out on;
//! unset = available parallelism — outcomes are identical at any count)

use std::path::Path;

use anyhow::Result;
use hosgd::attack::{build_task, run_attack, AttackConfig};
use hosgd::backend::{self, AttackBackend, Backend};
use hosgd::config::Method;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let method: Method = args.get(1).map(String::as_str).unwrap_or("ho_sgd").parse()?;
    let iters: u64 = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(200);

    let artifacts = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    let rt = backend::load_from_env("HOSGD_BACKEND", Path::new(artifacts))?;
    let bind = rt.attack()?;

    println!("training the frozen classifier (syncSGD, 300 iters)...");
    let task = build_task(rt.as_ref(), 7, 300)?;
    println!("classifier test accuracy: {:.3}", task.clf_test_acc);
    println!(
        "attacking n = {} images of class {} with {} (d = 900, m = 5, B = 5, lr = 30/d)",
        bind.eval_batch(),
        task.labels[0] as usize,
        method.paper_name()
    );

    let cfg = AttackConfig { method, iters, ..Default::default() };
    let out = run_attack(bind.as_ref(), &task, &cfg)?;

    println!("\niter   attack_loss");
    for row in out.trace.rows.iter().filter(|r| r.iter % (iters / 10).max(1) == 0) {
        println!("{:>4}   {:>11.5}", row.iter, row.train_loss);
    }

    println!("\nper-image outcome (Table 3 row):");
    for im in &out.images {
        println!(
            "  image {:>2}: {} -> {}  l2 = {:.3}  {}",
            im.index,
            im.true_label,
            im.adv_label,
            im.l2_distortion,
            if im.success { "fooled" } else { "held" }
        );
    }
    println!(
        "\nsuccess rate {:.0}%  least-l2 (Table 2 metric) {:?}  mean-l2 {:.3}",
        out.success_rate * 100.0,
        out.least_distortion,
        out.mean_distortion
    );
    Ok(())
}
