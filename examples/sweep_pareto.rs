//! Sweep the communication/computation/convergence tradeoff space and
//! print the Pareto frontier — the experiment-plan subsystem as a
//! library.
//!
//! Builds a small declarative plan (3 methods × 2 τ on the quickstart
//! profile), executes it in parallel through the sweep executor (each run
//! a private, bit-deterministic `Session`), and renders the Pareto
//! report: frontier chart, per-run summary and measured-vs-Table-1
//! deltas.
//!
//! Run with:
//!
//! ```sh
//! cargo run --release --example sweep_pareto [iters]
//! ```

use hosgd::prelude::*;
use hosgd::sweep::build_report;
use hosgd::util::json::Json;

fn main() -> Result<()> {
    let iters: u64 = std::env::args().nth(1).map_or(Ok(24), |s| s.parse())?;

    // the declarative plan — identical to a `hosgd sweep --plan` JSON file
    let base = TrainConfig {
        dataset: "quickstart".into(),
        iters,
        eval_every: (iters / 4).max(1),
        step: StepSize::Constant { alpha: 0.02 },
        threads: 1, // sweep-level parallelism is the concurrency here
        ..Default::default()
    };
    let plan = ExperimentPlan::new("example", base)
        .with_axis(
            "method",
            vec![Json::str("ho_sgd"), Json::str("sync_sgd"), Json::str("zo_sgd")],
        )
        .with_axis("tau", vec![Json::num(4.0), Json::num(8.0)])
        // ZO-SGD ignores τ; sweeping it would duplicate trajectories
        .with_override(
            vec![("method".into(), Json::str("zo_sgd"))],
            vec![("lr".into(), Json::num(0.005))],
        );
    let mut specs = plan.expand()?;
    // drop the duplicate zo_sgd×τ combination by label
    specs.retain(|s| !(s.label.contains("zo_sgd") && s.label.contains("tau=8")));
    println!("plan expands to {} runs:", specs.len());
    for s in &specs {
        println!("  {}", s.label);
    }

    let out_dir = std::env::temp_dir().join("hosgd_sweep_example");
    let opts = ExecOpts {
        artifacts: "artifacts".into(),
        out_dir: out_dir.clone(),
        manifest: out_dir.join("example.manifest.jsonl"),
        parallel: 0, // one lane per core
        workers_at: Vec::new(),
        threads: 0,
        resume: false,
        quiet: false,
    };
    let outcome = execute(&specs, &opts)?;
    println!(
        "\n{} executed, {} skipped (resumable via {:?})",
        outcome.executed, outcome.skipped, opts.manifest
    );

    let report = build_report("example", &specs, &outcome.rows)?;
    print!("\n{}", report.summary_table());
    print!("{}", report.frontier_chart());
    println!("measured vs analytic Table 1 rows:");
    print!("{}", report.delta_table());
    println!(
        "frontier: {}",
        report
            .frontier()
            .iter()
            .map(|e| e.row.label.as_str())
            .collect::<Vec<_>>()
            .join("  |  ")
    );
    Ok(())
}
