//! End-to-end driver (EXPERIMENTS.md §E2E) on the largest profile — now
//! written against the Session API: the run is stepped, observed through
//! the [`hosgd::coordinator::Observer`] event stream (live eval lines,
//! sync-round accounting), interrupted halfway, checkpointed to disk in
//! the v2 run-state format, restored in a fresh session and driven to the
//! horizon — demonstrating that an interrupted+resumed run is
//! bit-identical to an uninterrupted one (`rust/tests/resume.rs` asserts
//! this for every method).
//!
//! Run with: cargo run --release --example e2e_train [iters]
//!
//! `HOSGD_THREADS=N` sizes the parallel worker pool (unset = available
//! parallelism); at d ≈ 85k the batch-chunked native kernels and the
//! 4-worker fan-out both engage, and traces stay bit-identical.

use std::path::Path;

use hosgd::prelude::*;

/// Streams the run: one line per test evaluation, plus a count of the
/// vector-level synchronization rounds the τ schedule spaces out.
struct LiveLog {
    syncs: u64,
}

impl Observer for LiveLog {
    fn on_eval(&mut self, ev: &EvalEvent) {
        println!("  iter {:>5}  test_acc {:.3}", ev.iter, ev.accuracy);
    }
    fn on_sync_round(&mut self, ev: &SyncEvent) {
        self.syncs += 1;
        if self.syncs <= 3 {
            println!("  iter {:>5}  sync round: {} bytes/worker", ev.iter, ev.bytes);
        }
    }
}

fn main() -> Result<()> {
    let iters: u64 = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(300);
    let artifacts = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    let rt = hosgd::backend::load_from_env("HOSGD_BACKEND", Path::new(artifacts))?;
    let cfg = TrainConfig {
        method: Method::HoSgd,
        dataset: "e2e".into(),
        iters,
        workers: 4,
        tau: 8,
        step: StepSize::Constant { alpha: 0.002 }, // ZO-stable at d = 85k
        seed: 1,
        eval_every: (iters / 6).max(1),
        ..Default::default()
    };
    let model = rt.model(&cfg.dataset)?;
    println!(
        "e2e: d = {} params ({}→{}→{}→{}), m = {}, B = {}, tau = {}, N = {iters}",
        model.dim(),
        model.features(),
        model.meta().hidden1,
        model.meta().hidden2,
        model.classes(),
        cfg.workers,
        model.batch(),
        cfg.tau
    );

    let data = make_data(&cfg)?;

    // segment 1: run halfway, then snapshot to a v2 checkpoint file
    let half = iters / 2;
    let ckpt = std::env::temp_dir().join("hosgd_e2e_example.ck2");
    println!("\nsegment 1 (iterations 0..{half}):");
    let mut session = Session::new(model.as_ref(), &data, &cfg)?;
    session.add_observer(LiveLog { syncs: 0 });
    session.run_until(half)?;
    session.snapshot()?.save(&ckpt)?;
    println!("  checkpointed at iteration {} -> {}", session.iter(), ckpt.display());
    drop(session);

    // segment 2: restore from the bytes on disk and finish the horizon
    println!("segment 2 (resumed {half}..{iters}):");
    let state = RunState::load(&ckpt)?;
    let mut session = Session::restore(model.as_ref(), &data, &cfg, state)?;
    session.add_observer(LiveLog { syncs: 0 });
    session.run_to_end()?;

    let out = session.into_outcome()?;
    let first = out.trace.rows.first().unwrap();
    let last = out.trace.rows.last().unwrap();
    println!(
        "\nloss {:.4} -> {:.4}; final acc {:?}; {} scalars/worker (syncSGD: {})",
        first.train_loss,
        last.train_loss,
        out.trace.final_acc(),
        last.scalars_per_worker,
        iters * model.dim() as u64
    );
    out.trace.write_csv("results/e2e_example.csv")?;
    std::fs::remove_file(&ckpt).ok();
    println!("trace written to results/e2e_example.csv");
    Ok(())
}
