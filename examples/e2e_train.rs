//! End-to-end driver (EXPERIMENTS.md §E2E): exercise the full three-layer
//! stack on the largest AOT profile — Pallas dense kernels → JAX graphs →
//! HLO artifacts → rust coordinator — by training the `e2e` model
//! (d ≈ 85k parameters, scaled from the paper's 1.69M to the CPU-interpret
//! testbed) for several hundred HO-SGD iterations on a synthetic corpus,
//! logging the loss curve and test accuracy.
//!
//! Run with: cargo run --release --example e2e_train [iters]
//!
//! `HOSGD_THREADS=N` sizes the parallel worker pool (unset = available
//! parallelism); at d ≈ 85k the batch-chunked native kernels and the
//! 4-worker fan-out both engage, and traces stay bit-identical.

use std::path::Path;

use anyhow::Result;
use hosgd::backend::{self, Backend, ModelBackend};
use hosgd::config::{Method, StepSize, TrainConfig};
use hosgd::coordinator::{make_data, run_train_with};

fn main() -> Result<()> {
    let iters: u64 = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(300);
    let artifacts = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    let rt = backend::load_from_env("HOSGD_BACKEND", Path::new(artifacts))?;
    let cfg = TrainConfig {
        method: Method::HoSgd,
        dataset: "e2e".into(),
        iters,
        workers: 4,
        tau: 8,
        step: StepSize::Constant { alpha: 0.002 }, // ZO-stable at d = 85k
        seed: 1,
        eval_every: (iters / 12).max(1),
        ..Default::default()
    };
    let model = rt.model(&cfg.dataset)?;
    println!(
        "e2e: d = {} params ({}→{}→{}→{}), m = {}, B = {}, tau = {}, N = {iters}",
        model.dim(),
        model.features(),
        model.meta().hidden1,
        model.meta().hidden2,
        model.classes(),
        cfg.workers,
        model.batch(),
        cfg.tau
    );

    let data = make_data(&cfg)?;
    let out = run_train_with(model.as_ref(), &data, &cfg)?;

    println!("\niter   train_loss   test_acc     compute_s   comm_s(sim)");
    for row in &out.trace.rows {
        if row.test_acc.is_some() {
            println!(
                "{:>5}  {:>10.4}   {:>8.3}   {:>10.2}   {:>10.4}",
                row.iter,
                row.train_loss,
                row.test_acc.unwrap(),
                row.compute_s,
                row.comm_s
            );
        }
    }
    let last = out.trace.rows.last().unwrap();
    println!(
        "\nloss {:.4} -> {:.4}; final acc {:?}; {} scalars/worker (syncSGD: {})",
        out.trace.rows.first().unwrap().train_loss,
        last.train_loss,
        out.trace.final_acc(),
        last.scalars_per_worker,
        iters * model.dim() as u64
    );
    out.trace.write_csv("results/e2e_example.csv")?;
    println!("trace written to results/e2e_example.csv");
    Ok(())
}
