//! Quickstart: the smallest end-to-end use of the public API.
//!
//! Binds the default pure-rust backend (set `HOSGD_BACKEND=pjrt` for the
//! AOT artifacts), trains the tiny `quickstart` profile with HO-SGD (the
//! paper's Algorithm 1) for 200 iterations, and prints the loss curve plus
//! the communication/computation counters that make the method interesting.
//!
//! The m = 4 workers execute in parallel on the worker pool
//! (`HOSGD_THREADS=N`; unset = available parallelism). Traces are
//! bit-identical at any thread count — try `HOSGD_THREADS=1` vs `=4`.
//!
//! Run with: `cargo run --release --example quickstart`

use std::path::Path;

use anyhow::Result;
use hosgd::backend::{self, Backend, ModelBackend};
use hosgd::config::{Method, StepSize, TrainConfig};
use hosgd::coordinator::{make_data, run_train_with};
use hosgd::pool::resolve_threads;
use hosgd::theory::ratios;

fn main() -> Result<()> {
    let artifacts = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    let rt = backend::load_from_env("HOSGD_BACKEND", Path::new(artifacts))?;
    let lanes = std::env::var("HOSGD_THREADS")
        .ok()
        .and_then(|s| s.parse().ok())
        .map_or_else(|| resolve_threads(0), resolve_threads);
    println!("backend: {} ({}), {lanes} worker-pool lane(s)", rt.kind(), rt.platform());

    let cfg = TrainConfig {
        method: Method::HoSgd,
        dataset: "quickstart".into(),
        iters: 200,
        workers: 4,
        tau: 8,
        step: StepSize::Constant { alpha: 0.02 }, // ZO-stable at d = 499
        seed: 42,
        eval_every: 20,
        ..Default::default()
    };

    let model = rt.model(&cfg.dataset)?;
    println!(
        "model: d = {} parameters ({}→{}→{}→{}), batch {}",
        model.dim(),
        model.features(),
        model.meta().hidden1,
        model.meta().hidden2,
        model.classes(),
        model.batch()
    );

    let data = make_data(&cfg)?;
    let out = run_train_with(model.as_ref(), &data, &cfg)?;

    println!("\niter   train_loss   test_acc");
    for row in out.trace.rows.iter().filter(|r| r.iter % 20 == 0 || r.test_acc.is_some()) {
        println!(
            "{:>4}   {:>10.4}   {}",
            row.iter,
            row.train_loss,
            row.test_acc.map_or("-".into(), |a| format!("{a:.3}"))
        );
    }

    let last = out.trace.rows.last().unwrap();
    println!("\nfinal test accuracy: {:?}", out.trace.final_acc());
    println!(
        "communication: {} scalars/worker over {} iters (syncSGD would send {})",
        last.scalars_per_worker,
        cfg.iters,
        cfg.iters * model.dim() as u64,
    );
    println!(
        "compute: {} fn evals + {} grad evals; HO-SGD/FO compute ratio ≈ {:.4}",
        last.fn_evals,
        last.grad_evals,
        ratios::hosgd_over_fo_compute(model.dim(), cfg.tau),
    );
    Ok(())
}
